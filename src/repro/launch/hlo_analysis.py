"""Compiled-HLO analysis: collective bytes, memory stats, roofline terms.

The dry-run's "profiler": on CPU there is no wall-clock TPU trace, so the
roofline terms are derived structurally from the compiled artifact —
cost_analysis() for FLOPs/bytes, and the post-SPMD HLO text for the
collective schedule (op kinds x operand bytes), per the assignment.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.core.platform import HardwareSpec, TPU_V5E

__all__ = [
    "collective_stats",
    "memory_stats",
    "cost_stats",
    "RooflineTerms",
    "roofline_terms",
]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. "  %x = bf16[16,512]{1,0} all-reduce(%y), replica_groups=..." — in
# post-optimization HLO the operands are bare refs, so operand bytes are
# derived from the RESULT shape and the replica group size.
_OP_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_SET_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_SET_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        return max(len(ids), 1)
    return 1


def collective_stats(hlo_text: str) -> dict[str, Any]:
    """Per-device operand bytes of every collective in the compiled module.

    operand bytes by kind (result shape -> operand):
      all-reduce / all-to-all / collective-permute : operand == result
      all-gather                                   : operand == result / g
      reduce-scatter                               : operand == result * g
    wire bytes per device use ring-schedule factors — the quantity the
    roofline collective term is built from.
    """
    bytes_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    wire_by_kind: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        if m.group("start") and kind in ("all-gather",):
            # -start result tuple carries (operand, result); take the last
            shapes = _SHAPE_RE.findall(m.group("result"))
            shapes = shapes[-1:]
        else:
            shapes = _SHAPE_RE.findall(m.group("result"))
        result_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
        g = _group_size(line)
        if kind == "all-gather":
            operand = result_bytes // max(g, 1)
            wire = operand * (g - 1)                    # receives (g-1) shards
        elif kind == "reduce-scatter":
            operand = result_bytes * g
            wire = result_bytes * (g - 1)               # sends (g-1) shards
        elif kind == "all-reduce":
            operand = result_bytes
            wire = 2.0 * result_bytes * (g - 1) / max(g, 1)   # RS + AG ring
        else:  # all-to-all, collective-permute
            operand = result_bytes
            wire = result_bytes * (g - 1) / max(g, 1) if kind == "all-to-all" else result_bytes
        bytes_by_kind[kind] += operand
        wire_by_kind[kind] += wire
        count_by_kind[kind] += 1
    return {
        "bytes_by_kind": bytes_by_kind,
        "wire_by_kind": wire_by_kind,
        "count_by_kind": count_by_kind,
        "total_bytes": sum(bytes_by_kind.values()),
        "total_wire_bytes": float(sum(wire_by_kind.values())),
        "total_count": sum(count_by_kind.values()),
    }


def memory_stats(compiled) -> dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    fields = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {f: int(getattr(ma, f, 0)) for f in fields}
    out["peak_bytes_estimate"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out["alias_size_in_bytes"]
    )
    return out


def cost_stats(compiled) -> dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict[str, Any]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.step_time_lower_bound_s,
        }


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    chips: int,
    hw: HardwareSpec = TPU_V5E,
) -> RooflineTerms:
    """Per-assignment formulae (all quantities per device / per chip):

        compute    = FLOPs / peak_FLOP/s
        memory     = HBM bytes / HBM bw
        collective = collective bytes / ICI link bw
    """
    return RooflineTerms(
        compute_s=flops_per_device / hw.peak_flops_bf16,
        memory_s=bytes_per_device / hw.hbm_bandwidth,
        collective_s=collective_bytes_per_device / hw.ici_bandwidth,
        chips=chips,
    )
