"""Launchers: production mesh, dry-run, training and serving drivers.

NOTE: repro.launch.dryrun must be imported only in a fresh process (it
sets XLA_FLAGS for 512 host devices before importing jax).
"""

from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
