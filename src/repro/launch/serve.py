"""Production serving engine: chunked prefill + continuous batching.

The serving analogue of the paper's deployment story: the same bundle
that trained on the laptop serves on the pod, with the two compiled
paths a serving workload actually exercises —

  * **chunked prefill** — `Model.prefill_into` advances ONE slot of the
    batched cache by a fixed-width window of C prompt tokens per
    compiled step.  Prompt ingestion costs ceil(prompt_len / C) compiled
    steps instead of the O(prompt_len) whole-batch decode ticks the old
    prefill-by-decode loop burned (kept as ``prefill_mode="decode"``,
    the baseline row of benchmarks/table7_serving.py).
  * **batched decode** — one token for every active slot per compiled
    step, each slot at its own cache position (vector ``pos``), inactive
    slots parked at max_len-1 with their recurrent state frozen
    (``active`` mask).

Scheduling is split from compilation so it can be unit-tested with fake
clocks and fake engines:

  * `Scheduler` — pure-python continuous batching: FCFS admission from a
    bounded queue into fixed slots, a prefill/decode interleave ratio,
    per-request accounting (TTFT, compiled-step counts).  No jax.
  * `JaxEngine` — owns params/cache and the two jitted steps; counts
    every compiled-step invocation (the table7 scoreboard's honesty
    metric).
  * `Server` — the facade main() drives: Scheduler + JaxEngine + the
    request log.

Request lifecycle (documented in docs/serving.md):

    queued -> admitted (slot assigned) -> prefilling -> decoding -> done

Admission control rejects instead of deadlocking: a request is admitted
only if its prompt+generation budget fits the slot's cache window, and
`submit` bounces requests once the queue is `queue_depth` deep.

`--profile` / `--autotune` wire through both compiled paths unchanged:
every op call goes through the container's binding, so prefill
geometries (chunk_attention at C tokens) and decode geometries (Sq=1)
each resolve their own tuned configs — `print_dispatch_stats` shows
both after a run.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
import types
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import Runtime
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DeployOptions, make_deployment
from repro.launch.train import make_bundle

__all__ = ["BlockAllocator", "PagedPool", "Request", "Scheduler", "JaxEngine",
           "Server", "SERVING_STATS_SCHEMA", "DeploymentRejected",
           "estimate_footprint", "main"]

# scheduler states (docs/serving.md + docs/fleet.md state machines)
QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"
HANDOFF = "handoff"     # fleet mode: prefill finished, state in transit
DONE = "done"

# admission rejection reasons
REJECT_QUEUE_FULL = "queue-full"
REJECT_TOO_LONG = "too-long"

# Scheduler.consolidated_stats() keys — pinned, like the dispatch layer's
# STATS_SCHEMA: printers iterate this, so adding a counter here forces it
# into every consumer (and the schema test) at once.
SERVING_STATS_SCHEMA = frozenset({
    "submitted", "completed", "rejected-queue-full", "rejected-too-long",
    "handed-off", "adopted", "peak-active", "ticks",
    "pages-capacity", "pages-allocated-mean", "pages-written-mean",
    "pages-allocated-peak", "fragmentation-pct",
})


class DeploymentRejected(RuntimeError):
    """A deployment whose estimated footprint exceeds the memory budget.

    Raised by `JaxEngine` BEFORE any buffer is allocated, with the
    estimate attached — the caller (or table7's quantized-deploy row)
    reports exactly what did not fit and retries with ``quantize``."""

    def __init__(self, footprint: dict, budget: int):
        self.footprint = footprint
        self.budget = budget
        super().__init__(
            f"deployment needs ~{footprint['total_bytes']:,} bytes "
            f"(weights {footprint['weight_bytes']:,} + "
            f"kv {footprint['kv_bytes']:,}, quantize="
            f"{footprint['quantize']}) but the budget is {budget:,}")


def estimate_footprint(model, *, slots: int, max_len: int,
                       quantize: str | None = None, paged: bool = False,
                       num_pages: int | None = None,
                       page_size: int | None = None) -> dict:
    """Deployment memory estimate from abstract shapes — no allocation.

    Weights: quantizable leaves (the checkpoint quantizer's filter) cost
    1 byte per element plus fp32 per-channel scales when ``quantize`` is
    set, full dtype width otherwise.  KV: the model's abstract cache,
    which already reflects the storage dtype and scale leaves when the
    model was built with ``kv_quantize``."""
    import math

    from repro.checkpoint.manifest import _flatten, _quantizable

    wb = 0
    for path, s in _flatten(model.abstract_params()):
        n = math.prod(s.shape)
        if quantize and _quantizable(path, s):
            wb += n + (n // s.shape[-2]) * 4    # 1-byte codes + fp32 scales
        else:
            wb += n * jnp.dtype(s.dtype).itemsize
    cache = (model.abstract_paged_cache(num_pages, page_size, slots)
             if paged else model.abstract_cache(slots, max_len))
    kb = sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
             for s in jax.tree.leaves(cache))
    return {"weight_bytes": int(wb), "kv_bytes": int(kb),
            "total_bytes": int(wb + kb), "quantize": quantize or "none"}


@dataclasses.dataclass
class Request:
    """One generation request plus its complete serving record.

    The scheduler fills in the lifecycle fields; the benchmark reads
    them.  Timestamps come from the scheduler's injected clock, so a
    fake clock makes TTFT accounting exactly reproducible in tests.

    Attributes:
      rid: caller-chosen id (echoed in emitted (rid, token) pairs).
      prompt: (prompt_len,) int32 prompt tokens.
      max_new: generation budget; the scheduler may clamp it to its
        per-request cap at submit time.
      tokens: generated tokens (greedy argmax), filled during serving.
      state: queued -> prefilling -> decoding -> done.
      slot: cache row while admitted, else None.
      prefill_pos: prompt tokens ingested so far.
      next_pos: cache position the next fed token will be written to.
      submit_t / first_token_t / finish_t: clock readings; TTFT is
        first_token_t - submit_t (first token falls out of the final
        prefill chunk's logits on the chunked path, out of the first
        decode tick on the baseline path).
      prefill_steps / decode_steps: compiled steps this request consumed
        — the regression-pinned invariant is prefill_steps ==
        ceil(prompt_len / C) and decode_steps == max_new - 1 on the
        chunked path.
    """

    rid: int
    prompt: np.ndarray
    max_new: int
    tokens: list = dataclasses.field(default_factory=list)
    state: str = QUEUED
    slot: int | None = None
    prefill_pos: int = 0
    next_pos: int = 0
    submit_t: float | None = None
    first_token_t: float | None = None
    finish_t: float | None = None
    prefill_steps: int = 0
    decode_steps: int = 0
    order: int = -1     # FCFS sequence number, assigned at submit

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def ttft(self) -> float | None:
        if self.first_token_t is None or self.submit_t is None:
            return None
        return self.first_token_t - self.submit_t


class BlockAllocator:
    """Pure-python page bookkeeping for the paged KV cache.

    All-or-nothing allocation: `alloc(owner, n)` hands out n pages or
    None (never a partial grant — a half-provisioned request could not
    be admitted anyway), `free(owner)` returns every page the owner
    held.  Reserved pages (the park page) are never handed out.  The
    invariants the hypothesis suite pins (tests/test_block_allocator.py):
    no page is owned twice, free returns exactly what alloc granted, and
    pages-in-use never exceeds the pool.
    """

    def __init__(self, num_pages: int, *, reserved: int = 0):
        if num_pages <= reserved:
            raise ValueError(f"pool of {num_pages} pages with {reserved} reserved")
        self.num_pages = num_pages
        self.reserved = tuple(range(reserved))
        # stack of free page ids; pop() from the end -> lowest index first
        self._free = list(range(num_pages - 1, reserved - 1, -1))
        self.owned: dict[int, list[int]] = {}

    @property
    def capacity(self) -> int:
        return self.num_pages - len(self.reserved)

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, owner, n: int) -> list[int] | None:
        if owner in self.owned:
            raise ValueError(f"owner {owner!r} already holds pages")
        if n < 1:
            raise ValueError(f"alloc of {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.owned[owner] = pages
        return list(pages)

    def free(self, owner) -> list[int]:
        pages = self.owned.pop(owner, [])
        self._free.extend(pages)
        return list(pages)


class PagedPool:
    """BlockAllocator + per-slot block tables — the paged cache's map.

    Page size equals the prefill chunk C, so each compiled prefill step
    fills exactly one page.  Page 0 is reserved as the *park page*:
    inactive slots keep an all-zero table row, so their parked decode
    writes land there and their (masked, discarded) gathers read from
    there — the table never holds an out-of-pool index.  The default
    pool size (1 park + slots x max_blocks) matches the contiguous
    layout's capacity; pass `num_pages` to serve under memory pressure.
    """

    PARK = 0

    def __init__(self, slots: int, max_len: int, page_size: int,
                 num_pages: int | None = None):
        self.page_size = page_size
        self.max_blocks = -(-max_len // page_size)
        self.num_pages = (1 + slots * self.max_blocks
                          if num_pages is None else num_pages)
        self.allocator = BlockAllocator(self.num_pages, reserved=1)
        self.block_tables = np.zeros((slots, self.max_blocks), np.int32)

    def alloc(self, owner, n: int) -> list[int] | None:
        return self.allocator.alloc(owner, n)

    def free(self, owner) -> list[int]:
        return self.allocator.free(owner)

    def assign(self, slot: int, pages: list[int]) -> None:
        row = np.zeros(self.max_blocks, np.int32)
        row[: len(pages)] = pages
        self.block_tables[slot] = row

    def release(self, slot: int) -> None:
        self.block_tables[slot] = self.PARK


class JaxEngine:
    """The compiled half of the server: params, cache, two jitted steps.

    Owns the batched cache (slots x max_len) and exposes exactly the two
    operations the scheduler needs, both with static shapes so each
    compiles once:

      * prefill_step(slot, tokens, pos) — one prefill work unit.  In
        ``chunked`` mode this is Model.prefill_into over a C-wide window
        (slot/pos/n_valid traced — every request reuses one executable)
        and returns the window's last-token logits.  In ``decode`` mode
        (the baseline the old server implemented) it is ONE prompt token
        pushed through the whole-batch decode step, logits discarded —
        O(prompt_len) compiled ticks per request, kept so table7 can
        price the difference.
      * decode_step(tokens, pos, active) — one batched decode tick;
        every row at its own position, inactive rows parked at
        max_len-1 with recurrent state frozen.

    ``prefill_calls`` / ``decode_calls`` count compiled-step dispatches;
    the scoreboard derives per-request costs from the per-Request
    counters and cross-checks the totals against these.

    With ``paged=True`` the cache k/v are page *pools* (page size = C)
    addressed through ``self.pool``'s per-slot block tables; the
    scheduler drives the allocator (admission in pages actually needed)
    and this engine just threads the tables into both compiled steps.
    Paged mode requires chunked prefill — the page-per-chunk invariant
    is what keeps every prefill write inside one page.

    With ``window=W`` every attention call is sliding-window: a token
    attends only its trailing W keys (windowed decode/chunk_attention
    ABI).  The engine just threads the traced width into both compiled
    steps; the *scheduler* exploits it — out-of-window pages are parked
    and recycled, so a paged request's admission footprint is capped at
    ceil(W/page)+1 pages no matter how long it runs (docs/serving.md).
    """

    def __init__(self, cfg, container, *, slots: int, max_len: int,
                 chunk: int = 16, prefill_mode: str = "chunked",
                 paged: bool = False, num_pages: int | None = None,
                 window: int | None = None, quantize: str | None = None,
                 memory_budget: int | None = None):
        if prefill_mode not in ("chunked", "decode"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if chunk < 1 or chunk > max_len:
            raise ValueError(f"chunk {chunk} outside [1, max_len={max_len}]")
        if paged and prefill_mode != "chunked":
            raise ValueError("paged cache requires prefill_mode='chunked'")
        if window is not None and window < 1:
            raise ValueError(f"sliding window of {window} tokens")
        if quantize == "none":
            quantize = None
        if quantize is not None and quantize not in ("int8", "fp8"):
            raise ValueError(f"quantize must be int8/fp8/none, got {quantize!r}")
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.chunk = chunk
        self.prefill_mode = prefill_mode
        self.paged = paged
        self.window = window
        self.quantize = quantize
        shape = ShapeConfig("serve", max_len, slots, "decode")
        self.dep = make_deployment(
            cfg, shape, container.mesh,
            options=DeployOptions(donate=False, kv_quantize=quantize),
            binding=container.binding,
        )
        self.model = self.dep.model
        self.pool = PagedPool(slots, max_len, chunk, num_pages) if paged else None
        # admission control for the deployment itself: the footprint is
        # priced from abstract shapes and checked against the budget
        # BEFORE any weight or cache buffer exists, so an over-budget
        # config is rejected instead of OOM-killed mid-allocation.
        self.footprint = estimate_footprint(
            self.model, slots=slots, max_len=max_len, quantize=quantize,
            paged=paged, num_pages=self.pool.num_pages if paged else None,
            page_size=chunk if paged else None)
        if (memory_budget is not None
                and self.footprint["total_bytes"] > memory_budget):
            raise DeploymentRejected(self.footprint, memory_budget)
        params = self.model.init(jax.random.PRNGKey(0))
        if quantize is not None:
            from repro.checkpoint.manifest import quantize_tree

            # storage-form {"q", "scale"} subtrees no longer match the
            # per-leaf sharding tree, so quantized serving keeps default
            # placement (the single-host serving path)
            self.params = jax.tree.map(jnp.asarray,
                                       quantize_tree(params, quantize))
        else:
            self.params = jax.device_put(params, self.dep.param_sharding)
        if paged:
            self.cache = self.model.init_paged_cache(
                self.pool.num_pages, chunk, slots
            )
        else:
            self.cache = self.model.init_cache(slots, max_len)
        self._prefill = jax.jit(self.model.prefill_into)
        self._decode = jax.jit(self.model.decode)
        self.prefill_calls = 0
        self.decode_calls = 0

    # -- prefill ----------------------------------------------------------
    @property
    def prefill_unit(self) -> int:
        """Prompt tokens ingested per prefill_step call."""
        return self.chunk if self.prefill_mode == "chunked" else 1

    def prefill_step(self, slot: int, tokens: np.ndarray, pos: int):
        """Ingest one prefill unit into `slot` at cache position `pos`.

        tokens: (n,) int32 with 1 <= n <= prefill_unit.  Returns the
        logits (vocab,) of tokens[-1] in chunked mode, None in decode
        (baseline) mode — mirroring the old server, which discarded
        them and re-fed the last prompt token at position L to recover
        them, both wasting a tick AND conditioning the first generated
        token on a duplicated context token.  table7's baseline row
        prices the tick; tests/test_serving.py pins the replay.
        """
        n = int(tokens.shape[0])
        if self.prefill_mode == "chunked":
            buf = np.zeros((1, self.chunk), np.int32)
            buf[0, :n] = tokens
            kw = {}
            if self.paged:
                kw["block_row"] = jnp.asarray(self.pool.block_tables[slot])
            if self.window is not None:
                kw["window"] = jnp.int32(self.window)
            logits, self.cache = self._prefill(
                self.params, jnp.asarray(buf), self.cache,
                jnp.int32(slot), jnp.int32(pos), jnp.int32(n), **kw,
            )
            self.prefill_calls += 1
            return np.asarray(logits[0])
        # baseline: one whole-batch decode tick per prompt token
        assert n == 1
        tok = np.zeros((self.slots, 1), np.int32)
        tok[slot, 0] = int(tokens[0])
        posv = np.full(self.slots, self.max_len - 1, np.int32)
        posv[slot] = pos
        act = np.zeros(self.slots, bool)
        act[slot] = True
        kw = {}
        if self.window is not None:
            kw["window"] = jnp.int32(self.window)
        _, self.cache = self._decode(
            self.params, jnp.asarray(tok), self.cache,
            jnp.asarray(posv), jnp.asarray(act), **kw,
        )
        self.decode_calls += 1
        return None

    # -- decode -----------------------------------------------------------
    def decode_step(self, tokens: np.ndarray, pos: np.ndarray,
                    active: np.ndarray) -> np.ndarray:
        """One batched decode tick.  tokens (slots, 1), pos (slots,),
        active (slots,) bool; returns (slots, vocab) logits (garbage on
        inactive rows)."""
        kw = {}
        if self.paged:
            kw["block_tables"] = jnp.asarray(self.pool.block_tables)
        if self.window is not None:
            kw["window"] = jnp.int32(self.window)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(pos), jnp.asarray(active), **kw,
        )
        self.decode_calls += 1
        return np.asarray(logits)

    # -- KV handoff (the fleet's slot migration) --------------------------
    def export_slot(self, slot: int, n_tokens: int) -> tuple[dict, int]:
        """One slot's cache state out of the paged pools, for a KV handoff.

        ``n_tokens`` is the number of positions written so far (prompt
        length right after prefill; prompt + decoded on a mid-decode
        migration).  Returns ``(arrays, pages_used)``: the slot's written
        pages in block-table order plus its SSM rows, as host numpy —
        what `repro.tuning.bundle.KVHandoff` serializes.  Paged mode
        only: the contiguous layout has no per-slot page identity to
        ship.
        """
        if not self.paged:
            raise ValueError("slot export requires the paged cache")
        if n_tokens < 1:
            raise ValueError(f"export of {n_tokens} tokens")
        pages_used = -(-n_tokens // self.pool.page_size)
        pages = self.pool.block_tables[slot][:pages_used]
        return self.model.export_paged_slot(self.cache, pages, slot), pages_used

    def import_slot(self, slot: int, arrays: dict, pages_used: int) -> None:
        """Scatter a KV handoff into this engine's own pages.

        The receiving scheduler already leased this slot's pages from
        its own allocator (`Scheduler.adopt`); the handoff's page stack
        lands in the first ``pages_used`` entries of the slot's block
        table — page *numbering* never crosses replicas, only contents.
        """
        if not self.paged:
            raise ValueError("slot import requires the paged cache")
        pages = self.pool.block_tables[slot][:pages_used]
        self.cache = self.model.import_paged_slot(self.cache, arrays,
                                                  pages, slot)


class Scheduler:
    """Continuous batching policy: pure python, deterministic, no jax.

    One `tick()` is the scheduling quantum:

      1. **admit** — pop FCFS from the queue into free slots (requests
         were budget-checked at submit; admission just assigns slots).
      2. **prefill** — run up to `interleave` prefill work units, FCFS
         across prefilling requests.  The interleave ratio is the
         latency knob: higher drains prompts faster (better TTFT under
         prefill backlog), lower keeps decode ticks flowing (better
         per-token latency for running requests).
      3. **decode** — one batched decode tick if anything is decoding.

    Admission control (at `submit`):
      * queue bounded at `queue_depth` — excess rejected (queue-full);
      * `max_new` clamped to `max_new_cap`;
      * **contiguous**: the prompt+generation budget must fit one slot's
        cache window: prompt_len + max_new <= max_len AND every chunk's
        C-wide write window stays in bounds (ceil(prompt_len/C)*C <=
        max_len — conservative: the whole window is reserved up front);
        the baseline path needs one extra slot for its duplicated last
        prompt token.  Unfit requests are rejected (too-long), never
        queued — a queued request is guaranteed servable.
      * **paged**: the budget is counted in *pages actually needed*
        (ceil(budget / page)); a request is rejected only when that can
        never be satisfied (more pages than the block table holds or
        than exist in the pool).  A satisfiable request that finds the
        pool momentarily exhausted *queues* — `_admit` allocates pages
        FCFS and stops at the first request the pool cannot serve yet,
        so it admits as soon as a completion frees pages.

    The clock is injected so tests can drive TTFT accounting with a
    deterministic fake; the engine is injected so policy tests need no
    compiled model at all.

    **Fleet mode** (repro.serving) runs one Scheduler per replica as
    that replica's *local* policy.  ``on_handoff`` turns a scheduler
    into a prefill-pool policy: when a request's prompt is fully
    ingested it emits the first token, then — instead of decoding —
    calls the hook (with the slot still held, so the fleet can export
    the pages), releases the slot/pages locally, and marks the request
    HANDOFF.  `adopt` is the decode-pool counterpart: place a
    handed-off request straight into a free slot with pages leased from
    THIS engine's allocator, no queue and no prefill.
    """

    def __init__(self, engine, *, queue_depth: int = 64,
                 max_new_cap: int = 1 << 30, interleave: int = 2,
                 clock: Callable[[], float] = time.monotonic,
                 on_handoff: Callable[[Request], None] | None = None):
        if on_handoff is not None and engine.prefill_mode != "chunked":
            raise ValueError("handoff (prefill-pool role) requires chunked "
                             "prefill: the final chunk's logits are the "
                             "first token the handoff carries")
        self.engine = engine
        self.paged = bool(getattr(engine, "paged", False))
        # sliding-window width (getattr: policy tests drive fakes that
        # predate the windowed engine)
        self.window = getattr(engine, "window", None)
        self.queue_depth = queue_depth
        self.max_new_cap = max_new_cap
        self.interleave = max(1, interleave)
        self.clock = clock
        self.on_handoff = on_handoff
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * engine.slots
        # sliding-window page recycling: physical pages whose logical
        # block fell out of the attention window, banked per request
        # (keyed by order) until the write head claims a new block
        self._spare: dict[int, list[int]] = {}
        self.rejected: dict[str, int] = {}
        self.submitted = 0
        self.completed = 0
        self.handed_off = 0
        self.adopted = 0
        self.peak_active = 0
        self.ticks = 0
        # (pages allocated, pages holding written tokens) per tick — the
        # fragmentation series the table7 --paged scoreboard reports and
        # consolidated_stats() aggregates
        self.page_samples: list[tuple[int, int]] = []

    # -- admission --------------------------------------------------------
    def _budget(self, prompt_len: int, max_new: int) -> int:
        """Highest cache position + 1 this request can touch."""
        c = self.engine.prefill_unit
        chunks_end = -(-prompt_len // c) * c       # last chunk's write window
        gen_end = prompt_len + max_new
        if self.engine.prefill_mode == "decode":
            gen_end += 1                           # baseline re-feeds last token
        return max(chunks_end, gen_end)

    def _pages_needed(self, prompt_len: int, max_new: int, *,
                      capped: bool = True) -> int:
        """Pages a request must lease up front.

        With a sliding window the footprint is *capped*: logical blocks
        wholly behind the window are parked as the write head advances
        and their physical pages re-mapped to the blocks ahead
        (`_slide_window`), so at most ceil(W/page)+1 pages — the blocks
        the window straddles plus the one being written — are ever live.
        This is what shrinks windowed admission from O(prompt+gen) to
        O(window).  `capped=False` gives the uncapped count (`adopt`
        needs it: a KV handoff scatters the full written prefix, so the
        adopting slot's table must map every written block up front).
        """
        page = self.engine.pool.page_size
        full = -(-self._budget(prompt_len, max_new) // page)
        w = self.window
        if capped and w is not None:
            return min(full, -(-w // page) + 1)
        return full

    def servable(self, prompt_len: int, max_new: int) -> bool:
        """Can this request EVER be served by this engine's geometry?
        (The admission budget check, independent of momentary load —
        the fleet router uses it against a template replica.)"""
        if prompt_len < 1:
            return False
        if self.paged:
            pool = self.engine.pool
            # the block table must index every logical block the budget
            # touches (the window caps leased pages, not logical extent)
            if (self._pages_needed(prompt_len, max_new, capped=False)
                    > pool.max_blocks):
                return False
            return (self._pages_needed(prompt_len, max_new)
                    <= pool.allocator.capacity)
        return self._budget(prompt_len, max_new) <= self.engine.max_len

    def submit(self, req: Request) -> bool:
        """Admission-checked enqueue; returns False (and records why)
        when the request is rejected."""
        self.submitted += 1
        req.max_new = min(req.max_new, self.max_new_cap)
        if not self.servable(req.prompt_len, req.max_new):
            self.rejected[REJECT_TOO_LONG] = self.rejected.get(REJECT_TOO_LONG, 0) + 1
            return False
        if len(self.queue) >= self.queue_depth:
            self.rejected[REJECT_QUEUE_FULL] = self.rejected.get(REJECT_QUEUE_FULL, 0) + 1
            return False
        if req.order < 0:
            # the fleet pre-assigns globally-unique FCFS orders (one
            # allocator may host slots from many submit counters); a
            # standalone scheduler numbers its own
            req.order = self.submitted
        req.submit_t = self.clock()
        req.state = QUEUED
        self.queue.append(req)
        return True

    def adopt(self, req: Request) -> bool:
        """Decode-pool side of a KV handoff: place a handed-off request
        straight into a free slot, leasing its remaining-budget pages
        from THIS engine's allocator (the handoff contents are scattered
        by the caller via ``engine.import_slot`` once this returns True).
        Returns False when no slot or no pages are available right now —
        the fleet keeps the artifact pending and retries, exactly like
        paged admission queues on pool exhaustion."""
        slot = next((s for s in range(self.engine.slots)
                     if self.active[s] is None), None)
        if slot is None:
            return False
        if self.paged:
            # uncapped even under a sliding window: import_slot scatters
            # the handoff's full written prefix, so every written block
            # needs a mapped page; _slide_window recycles from there
            pages = self.engine.pool.alloc(
                req.order,
                self._pages_needed(req.prompt_len, req.max_new, capped=False),
            )
            if pages is None:
                return False
            self.engine.pool.assign(slot, pages)
        req.slot = slot
        req.state = DECODING
        self.active[slot] = req
        self.adopted += 1
        self.peak_active = max(
            self.peak_active, sum(r is not None for r in self.active)
        )
        return True

    def _admit(self) -> None:
        for s in range(self.engine.slots):
            if not self.queue:
                break
            if self.active[s] is not None:
                continue
            if self.paged:
                # FCFS in pages: allocate head-of-line's pages or wait —
                # skipping ahead would starve long requests forever
                req = self.queue[0]
                pages = self.engine.pool.alloc(
                    req.order, self._pages_needed(req.prompt_len, req.max_new)
                )
                if pages is None:
                    break                          # out of pages: stay queued
                self.queue.popleft()
                self.engine.pool.assign(s, pages)
            else:
                req = self.queue.popleft()
            req.slot = s
            req.state = PREFILLING
            req.prefill_pos = 0
            self.active[s] = req

    # -- lifecycle helpers ------------------------------------------------
    def _emit(self, req: Request, token: int, out: list) -> None:
        if req.first_token_t is None:
            req.first_token_t = self.clock()
        req.tokens.append(token)
        out.append((req.rid, token))
        if len(req.tokens) >= req.max_new:
            self._finish(req)

    def _finish(self, req: Request) -> None:
        req.state = DONE
        req.finish_t = self.clock()
        if self.paged:
            self._spare.pop(req.order, None)
            self.engine.pool.free(req.order)
            self.engine.pool.release(req.slot)
        self.active[req.slot] = None
        req.slot = None
        self.completed += 1

    def _handoff(self, req: Request) -> None:
        """Prefill-pool exit: hand the finished slot to the fleet (the
        hook exports the pages while the slot is still held), then
        release the local slot/pages — the artifact now carries the
        state, so this replica owes the request nothing further."""
        req.state = HANDOFF
        self.on_handoff(req)
        if self.paged:
            self._spare.pop(req.order, None)
            self.engine.pool.free(req.order)
            self.engine.pool.release(req.slot)
        self.active[req.slot] = None
        req.slot = None
        self.handed_off += 1

    def _slide_window(self, req: Request) -> None:
        """Sliding-window page recycling (paged + windowed engines only).

        A logical block whose last position can never be attended again
        ((j+1)*page <= head - W) is *dead*: its table entry is parked —
        the kernel's gather then reads the poison-inert park page and the
        window mask discards it — and its physical page is banked in the
        request's spare list.  The block the write head is about to enter
        is mapped from that bank.  Pages never return to the shared
        allocator mid-flight (another admission could snap them up and
        deadlock this request's next write); the lease cap in
        `_pages_needed` already priced the steady state, and everything
        goes back at `_finish`.  Repro note: live blocks are always the
        contiguous run [ (head-W)//page, head//page ], at most
        ceil(W/page)+1 of them — the lease cap.
        """
        pool = self.engine.pool
        w = self.window
        page = pool.page_size
        head = req.prefill_pos if req.state == PREFILLING else req.next_pos
        row = pool.block_tables[req.slot]
        spare = self._spare.setdefault(req.order, [])
        dead = max(0, head - w) // page
        spare.extend(int(p) for p in row[:dead] if p != pool.PARK)
        row[:dead] = pool.PARK
        nb = head // page                  # block the next write lands in
        if nb < pool.max_blocks and row[nb] == pool.PARK:
            # the lease cap guarantees a banked page is available here
            assert spare, "sliding-window lease underflow"
            row[nb] = spare.pop()

    # -- the quantum ------------------------------------------------------
    def tick(self) -> list[tuple[int, int]]:
        """Admit, prefill up to `interleave` units, one decode tick.
        Returns the (rid, token) pairs emitted this quantum."""
        self.ticks += 1
        self._admit()
        self.peak_active = max(
            self.peak_active, sum(r is not None for r in self.active)
        )
        out: list[tuple[int, int]] = []

        for _ in range(self.interleave):
            req = min(
                (r for r in self.active if r is not None and r.state == PREFILLING),
                key=lambda r: r.order, default=None,
            )
            if req is None:
                break
            if self.paged and self.window is not None:
                self._slide_window(req)
            n = min(self.engine.prefill_unit, req.prompt_len - req.prefill_pos)
            window = req.prompt[req.prefill_pos : req.prefill_pos + n]
            logits = self.engine.prefill_step(req.slot, window, req.prefill_pos)
            req.prefill_steps += 1
            req.prefill_pos += n
            if req.prefill_pos >= req.prompt_len:
                req.next_pos = req.prompt_len
                req.state = DECODING
                if logits is not None:
                    # chunked path: the final chunk's logits ARE the first
                    # token — no decode tick spent re-feeding the prompt
                    self._emit(req, int(np.argmax(logits)), out)
                if self.on_handoff is not None and not req.done:
                    # prefill-pool role: decode happens on another replica
                    self._handoff(req)

        decoding = [r for r in self.active if r is not None and r.state == DECODING]
        if decoding:
            if self.paged and self.window is not None:
                for r in decoding:
                    self._slide_window(r)
            tok = np.zeros((self.engine.slots, 1), np.int32)
            pos = np.full(self.engine.slots, self.engine.max_len - 1, np.int32)
            act = np.zeros(self.engine.slots, bool)
            for r in decoding:
                # baseline seeds from the re-fed last prompt token (its
                # prefill discarded the logits); chunked always has tokens
                tok[r.slot, 0] = r.tokens[-1] if r.tokens else int(r.prompt[-1])
                pos[r.slot] = r.next_pos
                act[r.slot] = True
            logits = self.engine.decode_step(tok, pos, act)
            for r in decoding:
                r.decode_steps += 1
                r.next_pos += 1
                self._emit(r, int(np.argmax(logits[r.slot])), out)
        if self.paged:
            page = self.engine.pool.page_size
            w = self.window
            used = 0
            for r in self.active:
                if r is None:
                    continue
                head = r.prefill_pos if r.state == PREFILLING else r.next_pos
                written = -(-head // page)
                if w is not None:
                    # recycled (out-of-window) blocks no longer hold
                    # readable tokens — count only the live window
                    written -= max(0, head - w) // page
                used += written
            self.page_samples.append((self.engine.pool.allocator.used, used))
        return out

    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.active)

    def consolidated_stats(self) -> dict[str, float]:
        """The schema-pinned serving counters, pool occupancy included.

        Every key in SERVING_STATS_SCHEMA is always present (0 on the
        contiguous path), mirroring the dispatch layer's consolidated
        stats: printers iterate the schema, so a new counter cannot be
        silently dropped from any output, and the per-tick
        ``page_samples`` series — previously reachable only from the
        benchmark — aggregates here for every consumer.
        """
        samples = self.page_samples
        alloc_mean = (sum(a for a, _ in samples) / len(samples)
                      if samples else 0.0)
        written_mean = (sum(w for _, w in samples) / len(samples)
                        if samples else 0.0)
        stats: dict[str, float] = {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected-queue-full": self.rejected.get(REJECT_QUEUE_FULL, 0),
            "rejected-too-long": self.rejected.get(REJECT_TOO_LONG, 0),
            "handed-off": self.handed_off,
            "adopted": self.adopted,
            "peak-active": self.peak_active,
            "ticks": self.ticks,
            "pages-capacity": (self.engine.pool.allocator.capacity
                               if self.paged else 0),
            "pages-allocated-mean": alloc_mean,
            "pages-written-mean": written_mean,
            "pages-allocated-peak": (max((a for a, _ in samples), default=0)
                                     if self.paged else 0),
            "fragmentation-pct": (100.0 * (1.0 - written_mean / alloc_mean)
                                  if alloc_mean else 0.0),
        }
        assert set(stats) == SERVING_STATS_SCHEMA
        return stats


class Server:
    """Scheduler + JaxEngine + request log — what main() and the
    benchmark drive.  `submit` admission-checks and records, `run`
    ticks until idle, `requests` holds every Request (accepted or not)
    with its full serving record."""

    def __init__(self, cfg, container, *, slots: int, max_len: int,
                 chunk: int = 16, prefill_mode: str = "chunked",
                 queue_depth: int = 64, max_new_cap: int = 1 << 30,
                 interleave: int = 2, paged: bool = False,
                 num_pages: int | None = None, window: int | None = None,
                 quantize: str | None = None,
                 memory_budget: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = JaxEngine(cfg, container, slots=slots, max_len=max_len,
                                chunk=chunk, prefill_mode=prefill_mode,
                                paged=paged, num_pages=num_pages,
                                window=window, quantize=quantize,
                                memory_budget=memory_budget)
        self.scheduler = Scheduler(self.engine, queue_depth=queue_depth,
                                   max_new_cap=max_new_cap,
                                   interleave=interleave, clock=clock)
        self.requests: list[Request] = []

    def submit(self, req: Request) -> bool:
        self.requests.append(req)
        return self.scheduler.submit(req)

    def step(self) -> list[tuple[int, int]]:
        return self.scheduler.tick()

    def run(self, max_ticks: int = 1 << 20) -> None:
        """Tick until every accepted request completes."""
        ticks = 0
        while not self.scheduler.idle:
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError("scheduler failed to drain (livelock?)")

    # old name, kept for callers of the previous server
    drain = run


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk width C: each compiled prefill step "
                         "ingests C prompt tokens into one slot")
    ap.add_argument("--prefill-mode", choices=("chunked", "decode"),
                    default="chunked",
                    help="'decode' replays the old prefill-by-decode loop "
                         "(O(prompt_len) whole-batch ticks) as a baseline")
    ap.add_argument("--paged", action="store_true",
                    help="page the KV cache (page size = --chunk) with "
                         "per-slot block tables; admission budgets in pages "
                         "actually needed (requires chunked prefill)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged pool size incl. the reserved park page "
                         "(default: 1 + slots * ceil(max_len/chunk), the "
                         "contiguous layout's capacity)")
    ap.add_argument("--window", type=int, default=None, metavar="W",
                    help="sliding-window attention: every token attends "
                         "only its trailing W keys; with --paged, "
                         "out-of-window pages are parked and recycled, "
                         "capping each request's admission footprint at "
                         "ceil(W/chunk)+1 pages")
    ap.add_argument("--quantize", choices=("none", "int8", "fp8"),
                    default="none",
                    help="serve with 1-byte weights (quant_matmul storage "
                         "subtrees) and a quantized KV cache — ~4x smaller "
                         "fp32 footprint (docs/quantization.md)")
    ap.add_argument("--memory-budget", type=int, default=None, metavar="BYTES",
                    help="reject the deployment (DeploymentRejected) if the "
                         "estimated weights+KV footprint exceeds this")
    ap.add_argument("--queue-depth", type=int, default=64,
                    help="admission control: submits beyond this queue depth "
                         "are rejected, not buffered")
    ap.add_argument("--interleave", type=int, default=2,
                    help="prefill work units per scheduler tick (the "
                         "prefill/decode interleave ratio)")
    ap.add_argument("--native-ops", action="store_true",
                    help="swap in native kernels where the platform has them "
                         "(or set REPRO_NATIVE_OPS=1; references have no "
                         "tuner, so autotune needs this)")
    ap.add_argument("--profile", action="store_true",
                    help="capture op geometries into REPRO_WORKLOAD_PROFILE "
                         "(feed repro.tuning.warm; or set REPRO_PROFILE=1)")
    ap.add_argument("--autotune", action="store_true",
                    help="resolve kernel configs from the site tuning cache "
                         "(or set REPRO_AUTOTUNE=1)")
    ap.add_argument("--max-tuned-entries", type=int, default=None, metavar="K",
                    help="per-op cap on the geometry-dispatch table; cold "
                         "cached buckets beyond it are LRU-evicted "
                         "(or set REPRO_TUNING_MAX_ENTRIES)")
    ap.add_argument("--tuning-bundle", default=None, metavar="PATH",
                    help="portable tuning bundle to import before binding "
                         "(python -m repro.tuning.bundle export; or set "
                         "REPRO_TUNING_BUNDLE) — entries revalidate against "
                         "this platform, so a laptop-warmed artifact deploys "
                         "here with zero searches")
    args = ap.parse_args(argv)

    bundle = make_bundle(args.arch, reduced=True)
    runtime = Runtime()
    container = runtime.deploy(bundle, mesh=make_host_mesh(data=1),
                               native_ops=True if args.native_ops else None,
                               profile=True if args.profile else None,
                               autotune=True if args.autotune else None,
                               max_tuned_entries=args.max_tuned_entries,
                               tuning_bundle=args.tuning_bundle)
    cfg = get_config(args.arch).reduced()

    try:
        server = Server(cfg, container, slots=args.slots, max_len=args.max_len,
                        chunk=args.chunk, prefill_mode=args.prefill_mode,
                        queue_depth=args.queue_depth, paged=args.paged,
                        num_pages=args.num_pages, window=args.window,
                        quantize=args.quantize,
                        memory_budget=args.memory_budget)
    except DeploymentRejected as e:
        print(f"deployment rejected: {e}")
        runtime.cleanup()
        return 2
    fp = server.engine.footprint
    print(f"footprint: weights {fp['weight_bytes']:,}B + "
          f"kv {fp['kv_bytes']:,}B = {fp['total_bytes']:,}B "
          f"(quantize={fp['quantize']})")
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(2, 6)).astype(np.int32)
        server.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    server.run()
    dt = time.time() - t0

    done = [r for r in server.requests if r.done]
    total_tokens = sum(len(r.tokens) for r in done)
    ttfts = sorted(r.ttft for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"prefill_mode={args.prefill_mode})")
    if ttfts:
        print(f"TTFT p50 {ttfts[len(ttfts) // 2] * 1e3:.1f}ms "
              f"max {ttfts[-1] * 1e3:.1f}ms | compiled steps: "
              f"prefill={server.engine.prefill_calls} "
              f"decode={server.engine.decode_calls}")
    if server.scheduler.rejected:
        print("rejected: " + " ".join(
            f"{k}={v}" for k, v in sorted(server.scheduler.rejected.items())))
    if args.paged:
        pool = server.engine.pool
        stats = server.scheduler.consolidated_stats()
        print(f"paged pool: {pool.num_pages} pages x {pool.page_size} tokens "
              f"(park+{int(stats['pages-capacity'])}) | "
              f"peak_active={int(stats['peak-active'])} | "
              f"pages allocated/used mean "
              f"{stats['pages-allocated-mean']:.1f}"
              f"/{stats['pages-written-mean']:.1f} "
              f"(fragmentation {stats['fragmentation-pct']:.0f}%)")
    if container.workload is not None:
        print(f"captured {len(container.workload)} op geometries -> "
              f"{container.workload.path} (warm with: python -m repro.tuning.warm)")
    print_dispatch_stats(container)
    runtime.cleanup()
    return 0


def print_dispatch_stats(container) -> None:
    """Per-op geometry-dispatch stats after an autotuned run, from the one
    consolidated (schema-pinned) stats dict: how many compiled geometries
    resolved their own tuned entry (exact) vs fell back to the nearest
    bucket, a dtype-crossing borrow, a demoted bundle candidate, or the
    platform default — plus table fullness/size and the bind-time
    lifecycle counters (LRU eviction, bundle import outcomes).  Iterating
    the schema (not an ad hoc format string) is what guarantees a new
    counter cannot be silently dropped from this output."""
    if not container.autotune:
        return
    from repro.tuning.dispatch import DISPATCH_PATHS, consolidated_stats

    if container.tuning_imports is not None:
        c = container.tuning_imports.counts()
        print(f"tuning bundle [{container.tuning_imports.source}]: "
              + " ".join(f"{k}={v}" for k, v in sorted(c.items())))
    reports = {r.op: r for r in container.binding.reports}
    for name in container.binding:
        impl = container.binding.impl(name)
        dispatch = getattr(impl.fn, "stats", None)
        # impl.config survives the profiled_binding wrap; impl.fn.stats is
        # forwarded through it, but consolidated_stats needs the dispatch
        # object itself — reconstruct a view from config + stats
        table = getattr(impl, "config", None)
        if dispatch is None or table is None or not hasattr(table, "stats"):
            continue
        if not sum(dispatch.values()):
            continue
        # the profiled wrapper hides the TunedDispatch instance but forwards
        # its counters; a facade with .stats/.table is all the consolidation
        # needs
        view = types.SimpleNamespace(stats=dispatch, table=table)
        stats = consolidated_stats(view, reports[name].geometries)
        total = sum(stats[p] for p in DISPATCH_PATHS)
        parts = " ".join(f"{p}={stats[p]}" for p in DISPATCH_PATHS)
        line = (f"dispatch {name:<18} {total} "
                f"geometr{'y' if total == 1 else 'ies'} traced: {parts}")
        line += (f" | table {stats['table-entries']}"
                 + (f"/{stats['table-cap']}" if stats["table-cap"] else "")
                 + (f" (+{stats['table-demoted']} demoted)"
                    if stats["table-demoted"] else "")
                 + f" ~{stats['table-bytes']}B")
        lifecycle = " ".join(
            f"{k}={stats[k]}" for k in ("evicted-lru", "bundle-imported",
                                        "bundle-demoted", "bundle-rejected")
            if stats[k])
        if lifecycle:
            line += f" | {lifecycle}"
        print(line)


if __name__ == "__main__":
    raise SystemExit(main())
