"""Serving driver: prefill + batched decode with a continuous request queue.

The serving analogue of the paper's deployment story: the same bundle that
trained on the laptop serves on the pod — prefill fills the KV/SSM caches,
then a batched decode loop streams tokens for every active request, with
slot-based continuous batching (a finished request's slot is refilled from
the queue without recompiling — static shapes throughout).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import Runtime
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DeployOptions, make_deployment
from repro.launch.train import make_bundle

__all__ = ["Server", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new: int
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot batched decoder (static shapes; slots refilled in place)."""

    def __init__(self, cfg, container, *, slots: int, max_len: int):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        shape = ShapeConfig("serve", max_len, slots, "decode")
        self.dep = make_deployment(
            cfg, shape, container.mesh,
            options=DeployOptions(donate=False),
            binding=container.binding,
        )
        self.model = self.dep.model
        params = self.model.init(jax.random.PRNGKey(0))
        self.params = jax.device_put(params, self.dep.param_sharding)
        self.cache = self.model.init_cache(slots, max_len)
        self.pos = np.zeros(slots, np.int32)          # per-slot write position
        self.active: list[Request | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(self.model.decode)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                # prefill-by-decode: feed prompt tokens through the decode
                # path into this slot's cache region (single-slot serving
                # keeps one compiled step; a production server would batch
                # prompt prefill separately).
                self.active[s] = req
                self.pos[s] = 0
                for t in req.prompt:
                    self._step_slot(s, int(t))

    def _step_slot(self, slot: int, token: int) -> int:
        tok = np.zeros((self.slots, 1), np.int32)
        tok[slot, 0] = token
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tok), self.cache, jnp.int32(self.pos[slot])
        )
        self.pos[slot] += 1
        return int(jnp.argmax(logits[slot]))

    def step(self) -> list[tuple[int, int]]:
        """One decode tick across all active slots; returns (rid, token)."""
        self._fill_slots()
        emitted = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            last = req.tokens[-1] if req.tokens else int(req.prompt[-1])
            nxt = self._step_slot(s, last)
            req.tokens.append(nxt)
            emitted.append((req.rid, nxt))
            if len(req.tokens) >= req.max_new or self.pos[s] >= self.max_len - 1:
                req.done = True
                self.active[s] = None
        return emitted

    def drain(self) -> None:
        while self.queue or any(self.active):
            self.step()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--native-ops", action="store_true",
                    help="swap in native kernels where the platform has them "
                         "(or set REPRO_NATIVE_OPS=1; references have no "
                         "tuner, so autotune needs this)")
    ap.add_argument("--profile", action="store_true",
                    help="capture op geometries into REPRO_WORKLOAD_PROFILE "
                         "(feed repro.tuning.warm; or set REPRO_PROFILE=1)")
    ap.add_argument("--autotune", action="store_true",
                    help="resolve kernel configs from the site tuning cache "
                         "(or set REPRO_AUTOTUNE=1)")
    ap.add_argument("--max-tuned-entries", type=int, default=None, metavar="K",
                    help="per-op cap on the geometry-dispatch table; cold "
                         "cached buckets beyond it are LRU-evicted "
                         "(or set REPRO_TUNING_MAX_ENTRIES)")
    args = ap.parse_args(argv)

    bundle = make_bundle(args.arch, reduced=True)
    runtime = Runtime()
    container = runtime.deploy(bundle, mesh=make_host_mesh(data=1),
                               native_ops=True if args.native_ops else None,
                               profile=True if args.profile else None,
                               autotune=True if args.autotune else None,
                               max_tuned_entries=args.max_tuned_entries)
    cfg = get_config(args.arch).reduced()

    server = Server(cfg, container, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(2, 6)).astype(np.int32)
        server.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    server.drain()
    dt = time.time() - t0
    total_tokens = args.requests * args.max_new
    print(f"served {args.requests} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    if container.workload is not None:
        print(f"captured {len(container.workload)} op geometries -> "
              f"{container.workload.path} (warm with: python -m repro.tuning.warm)")
    print_dispatch_stats(container)
    runtime.cleanup()
    return 0


def print_dispatch_stats(container) -> None:
    """Per-op geometry-dispatch hit rates after an autotuned run: how many
    compiled geometries resolved their own tuned entry (exact) vs fell
    back to the nearest bucket, a dtype-crossing borrow, or the platform
    default — plus, under a table cap, how full each op's table is and
    how many cold buckets the bind shed (cache-evicted-lru)."""
    if not container.autotune:
        return
    reports = {r.op: r for r in container.binding.reports}
    for name in container.binding:
        dispatch = container.binding.impl(name).fn
        stats = getattr(dispatch, "stats", None)
        if not stats or not sum(stats.values()):
            continue
        total = sum(stats.values())
        line = (f"dispatch {name:<18} {total} "
                f"geometr{'y' if total == 1 else 'ies'} traced:"
                f" exact={stats['exact']} nearest={stats['nearest']}"
                f" near-dtype={stats.get('near-dtype', 0)}"
                f" default={stats['default']} explicit={stats['explicit']}")
        # impl.config survives the profiled_binding wrap; dispatch.table
        # would not
        table = getattr(container.binding.impl(name), "config", None)
        if table is not None and getattr(table, "max_entries", None):
            evicted = sum(g.status == "cache-evicted-lru"
                          for g in reports[name].geometries)
            line += (f" | table {len(table)}/{table.max_entries}"
                     + (f" (evicted-lru={evicted})" if evicted else ""))
        print(line)


if __name__ == "__main__":
    raise SystemExit(main())
