"""Serving driver: prefill + batched decode with a continuous request queue.

The serving analogue of the paper's deployment story: the same bundle that
trained on the laptop serves on the pod — prefill fills the KV/SSM caches,
then a batched decode loop streams tokens for every active request, with
slot-based continuous batching (a finished request's slot is refilled from
the queue without recompiling — static shapes throughout).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
import types
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import Runtime
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import DeployOptions, make_deployment
from repro.launch.train import make_bundle

__all__ = ["Server", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new: int
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot batched decoder (static shapes; slots refilled in place)."""

    def __init__(self, cfg, container, *, slots: int, max_len: int):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        shape = ShapeConfig("serve", max_len, slots, "decode")
        self.dep = make_deployment(
            cfg, shape, container.mesh,
            options=DeployOptions(donate=False),
            binding=container.binding,
        )
        self.model = self.dep.model
        params = self.model.init(jax.random.PRNGKey(0))
        self.params = jax.device_put(params, self.dep.param_sharding)
        self.cache = self.model.init_cache(slots, max_len)
        self.pos = np.zeros(slots, np.int32)          # per-slot write position
        self.active: list[Request | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(self.model.decode)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                # prefill-by-decode: feed prompt tokens through the decode
                # path into this slot's cache region (single-slot serving
                # keeps one compiled step; a production server would batch
                # prompt prefill separately).
                self.active[s] = req
                self.pos[s] = 0
                for t in req.prompt:
                    self._step_slot(s, int(t))

    def _step_slot(self, slot: int, token: int) -> int:
        tok = np.zeros((self.slots, 1), np.int32)
        tok[slot, 0] = token
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tok), self.cache, jnp.int32(self.pos[slot])
        )
        self.pos[slot] += 1
        return int(jnp.argmax(logits[slot]))

    def step(self) -> list[tuple[int, int]]:
        """One decode tick across all active slots; returns (rid, token)."""
        self._fill_slots()
        emitted = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            last = req.tokens[-1] if req.tokens else int(req.prompt[-1])
            nxt = self._step_slot(s, last)
            req.tokens.append(nxt)
            emitted.append((req.rid, nxt))
            if len(req.tokens) >= req.max_new or self.pos[s] >= self.max_len - 1:
                req.done = True
                self.active[s] = None
        return emitted

    def drain(self) -> None:
        while self.queue or any(self.active):
            self.step()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--native-ops", action="store_true",
                    help="swap in native kernels where the platform has them "
                         "(or set REPRO_NATIVE_OPS=1; references have no "
                         "tuner, so autotune needs this)")
    ap.add_argument("--profile", action="store_true",
                    help="capture op geometries into REPRO_WORKLOAD_PROFILE "
                         "(feed repro.tuning.warm; or set REPRO_PROFILE=1)")
    ap.add_argument("--autotune", action="store_true",
                    help="resolve kernel configs from the site tuning cache "
                         "(or set REPRO_AUTOTUNE=1)")
    ap.add_argument("--max-tuned-entries", type=int, default=None, metavar="K",
                    help="per-op cap on the geometry-dispatch table; cold "
                         "cached buckets beyond it are LRU-evicted "
                         "(or set REPRO_TUNING_MAX_ENTRIES)")
    ap.add_argument("--tuning-bundle", default=None, metavar="PATH",
                    help="portable tuning bundle to import before binding "
                         "(python -m repro.tuning.bundle export; or set "
                         "REPRO_TUNING_BUNDLE) — entries revalidate against "
                         "this platform, so a laptop-warmed artifact deploys "
                         "here with zero searches")
    args = ap.parse_args(argv)

    bundle = make_bundle(args.arch, reduced=True)
    runtime = Runtime()
    container = runtime.deploy(bundle, mesh=make_host_mesh(data=1),
                               native_ops=True if args.native_ops else None,
                               profile=True if args.profile else None,
                               autotune=True if args.autotune else None,
                               max_tuned_entries=args.max_tuned_entries,
                               tuning_bundle=args.tuning_bundle)
    cfg = get_config(args.arch).reduced()

    server = Server(cfg, container, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(2, 6)).astype(np.int32)
        server.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    server.drain()
    dt = time.time() - t0
    total_tokens = args.requests * args.max_new
    print(f"served {args.requests} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    if container.workload is not None:
        print(f"captured {len(container.workload)} op geometries -> "
              f"{container.workload.path} (warm with: python -m repro.tuning.warm)")
    print_dispatch_stats(container)
    runtime.cleanup()
    return 0


def print_dispatch_stats(container) -> None:
    """Per-op geometry-dispatch stats after an autotuned run, from the one
    consolidated (schema-pinned) stats dict: how many compiled geometries
    resolved their own tuned entry (exact) vs fell back to the nearest
    bucket, a dtype-crossing borrow, a demoted bundle candidate, or the
    platform default — plus table fullness/size and the bind-time
    lifecycle counters (LRU eviction, bundle import outcomes).  Iterating
    the schema (not an ad hoc format string) is what guarantees a new
    counter cannot be silently dropped from this output."""
    if not container.autotune:
        return
    from repro.tuning.dispatch import DISPATCH_PATHS, consolidated_stats

    if container.tuning_imports is not None:
        c = container.tuning_imports.counts()
        print(f"tuning bundle [{container.tuning_imports.source}]: "
              + " ".join(f"{k}={v}" for k, v in sorted(c.items())))
    reports = {r.op: r for r in container.binding.reports}
    for name in container.binding:
        impl = container.binding.impl(name)
        dispatch = getattr(impl.fn, "stats", None)
        # impl.config survives the profiled_binding wrap; impl.fn.stats is
        # forwarded through it, but consolidated_stats needs the dispatch
        # object itself — reconstruct a view from config + stats
        table = getattr(impl, "config", None)
        if dispatch is None or table is None or not hasattr(table, "stats"):
            continue
        if not sum(dispatch.values()):
            continue
        # the profiled wrapper hides the TunedDispatch instance but forwards
        # its counters; a facade with .stats/.table is all the consolidation
        # needs
        view = types.SimpleNamespace(stats=dispatch, table=table)
        stats = consolidated_stats(view, reports[name].geometries)
        total = sum(stats[p] for p in DISPATCH_PATHS)
        parts = " ".join(f"{p}={stats[p]}" for p in DISPATCH_PATHS)
        line = (f"dispatch {name:<18} {total} "
                f"geometr{'y' if total == 1 else 'ies'} traced: {parts}")
        line += (f" | table {stats['table-entries']}"
                 + (f"/{stats['table-cap']}" if stats["table-cap"] else "")
                 + (f" (+{stats['table-demoted']} demoted)"
                    if stats["table-demoted"] else "")
                 + f" ~{stats['table-bytes']}B")
        lifecycle = " ".join(
            f"{k}={stats[k]}" for k in ("evicted-lru", "bundle-imported",
                                        "bundle-demoted", "bundle-rejected")
            if stats[k])
        if lifecycle:
            line += f" | {lifecycle}"
        print(line)


if __name__ == "__main__":
    raise SystemExit(main())
