import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) deployment is
coherent — lower + compile under the production mesh, record memory and
cost analysis and the collective schedule.

The two lines above MUST precede any jax import: the 512 placeholder
host devices let jax.make_mesh build the production meshes on this CPU
container.  Smoke tests and benchmarks do NOT import this module.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] --out experiments/dryrun

--all orchestrates one subprocess per cell (fresh XLA memory per compile).
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from pathlib import Path

__all__ = ["run_cell", "main"]

_DEF_OUT = Path("experiments/dryrun")


def _lower_and_compile(dep, shape):
    t0 = time.time()
    if shape.kind == "train":
        params, opt = dep.abstract_state()
        lowered = dep.train_step.lower(params, opt, dep.abstract_batch())
    elif shape.kind == "prefill":
        params, _ = dep.abstract_state()
        lowered = dep.prefill_step.lower(params, dep.abstract_batch())
    else:
        params, _ = dep.abstract_state()
        b = dep.abstract_batch()
        lowered = dep.decode_step.lower(params, b["token"], b["cache"], b["pos"])
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return lowered, compiled, t_lower, t_compile


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    seq_shard: bool = False,
    remat: str | None = None,
    rules: str = "baseline",
    label: str = "baseline",
    moe_chunks: int = 1,
    loss_chunks: int = 1,
    grad_accum: int = 1,
    head_padding: bool = True,
    cache_seq_shard: bool = True,
) -> dict:
    import dataclasses as dc

    from repro.configs import get_config, get_shape, shape_applicable
    from repro.launch import perf_variants
    from repro.launch.hlo_analysis import (
        collective_stats,
        cost_stats,
        memory_stats,
        roofline_terms,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import DeployOptions, make_deployment

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    result: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "label": label,
        "kind": shape.kind,
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    options = DeployOptions(
        remat=remat, seq_shard=seq_shard, rules=perf_variants.get_rules(rules),
        moe_token_chunks=moe_chunks, loss_seq_chunks=loss_chunks,
        grad_accum=grad_accum,
        head_padding=head_padding, cache_seq_shard=cache_seq_shard,
    )

    # -- 1. full-depth compile (scanned): the deployment PROOF + memory ----
    dep = make_deployment(cfg, shape, mesh, options=options)
    _, compiled, t_lower, t_compile = _lower_and_compile(dep, shape)
    mem = memory_stats(compiled)

    # -- 2. cost extrapolation: XLA's cost_analysis counts while bodies once,
    # so flops/bytes/collectives come from small UNROLLED depth-1/depth-2
    # models: total = c1 + (n_blocks - 1) * (c2 - c1) [+ encoder delta].
    from repro.models.model import build_model  # for period calculation

    period = build_model(cfg).period
    n_blocks = cfg.num_layers // period
    opts_u = dc.replace(options, scan_unroll=True)

    def cost_at(dec_blocks: int, enc_layers: int | None = None):
        kw = {"num_layers": period * dec_blocks}
        if cfg.encoder_layers:
            kw["encoder_layers"] = enc_layers if enc_layers is not None else 1
        cfg_k = dc.replace(cfg, **kw)
        dep_k = make_deployment(cfg_k, shape, mesh, options=opts_u)
        _, compiled_k, _, _ = _lower_and_compile(dep_k, shape)
        c = cost_stats(compiled_k)
        col = collective_stats(compiled_k.as_text())
        return {
            "flops": c.get("flops", 0.0),
            "bytes": c.get("bytes_accessed", 0.0),
            "coll_operand": float(col["total_bytes"]),
            "coll_wire": float(col["total_wire_bytes"]),
            "coll_counts": col["count_by_kind"],
            "coll_bytes_by_kind": col["bytes_by_kind"],
        }

    c1 = cost_at(1)
    c2 = cost_at(2)
    scale = n_blocks - 1

    def extrap(key):
        # linear in depth; clamped because XLA's collective combiner can be
        # mildly sublinear between depth-1 and depth-2 modules
        return max(c1[key] + scale * (c2[key] - c1[key]), max(c1[key], c2[key]))

    cost = {k: extrap(k) for k in ("flops", "bytes", "coll_operand", "coll_wire")}
    coll_counts = {
        k: c1["coll_counts"][k] + scale * (c2["coll_counts"][k] - c1["coll_counts"][k])
        for k in c1["coll_counts"]
    }
    coll_bytes_kind = {
        k: c1["coll_bytes_by_kind"][k]
        + scale * (c2["coll_bytes_by_kind"][k] - c1["coll_bytes_by_kind"][k])
        for k in c1["coll_bytes_by_kind"]
    }
    if cfg.encoder_layers > 1:
        c_enc2 = cost_at(1, enc_layers=2)
        enc_scale = cfg.encoder_layers - 1
        for k in cost:
            src = {"flops": "flops", "bytes": "bytes",
                   "coll_operand": "coll_operand", "coll_wire": "coll_wire"}[k]
            cost[k] += enc_scale * (c_enc2[src] - c1[src])

    # model-level FLOPs (assignment conventions); enc-dec processes S
    # encoder frames AND S decoder tokens -> 2x positions per cell
    total_p, active_p = cfg.param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if cfg.is_enc_dec and shape.kind != "decode":
        tokens *= 2
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * active_p * tokens

    flops_dev = cost["flops"]
    terms = roofline_terms(flops_dev, cost["bytes"], cost["coll_operand"], chips)
    result.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem,
        cost={
            "flops": flops_dev,
            "bytes_accessed": cost["bytes"],
            "collective_operand_bytes": cost["coll_operand"],
            "collective_wire_bytes": cost["coll_wire"],
        },
        collectives={
            "bytes_by_kind": coll_bytes_kind,
            "count_by_kind": coll_counts,
            "total_bytes": cost["coll_operand"],
            "total_wire_bytes": cost["coll_wire"],
        },
        chips=chips,
        period=period,
        n_blocks=n_blocks,
        params_total=total_p,
        params_active=active_p,
        model_flops_total=model_flops,
        model_flops_per_chip=model_flops / chips,
        useful_flops_ratio=(model_flops / chips) / flops_dev if flops_dev else None,
        roofline=terms.as_dict(),
    )
    return result


def _print_summary(r: dict) -> None:
    if r["status"] != "ok":
        print(f"[{r['arch']} x {r['shape']} @ {r['mesh']}] {r['status']}: "
              f"{r.get('reason', r.get('error', ''))}")
        return
    mem = r["memory"]
    print(
        f"[{r['arch']} x {r['shape']} @ {r['mesh']} ({r['label']})] OK "
        f"compile={r['compile_s']}s\n"
        f"  per-device bytes: args={mem.get('argument_size_in_bytes', 0)/1e9:.3f}G "
        f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.3f}G "
        f"out={mem.get('output_size_in_bytes', 0)/1e9:.3f}G\n"
        f"  per-device flops={r['cost']['flops']:.3e} "
        f"hbm_bytes={r['cost']['bytes_accessed']:.3e} "
        f"coll_bytes={r['collectives']['total_bytes']:.3e}\n"
        f"  roofline: compute={r['roofline']['compute_s']*1e3:.2f}ms "
        f"memory={r['roofline']['memory_s']*1e3:.2f}ms "
        f"collective={r['roofline']['collective_s']*1e3:.2f}ms "
        f"-> {r['roofline']['dominant']}-bound\n"
        f"  useful_flops_ratio={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)}"
    )


def _cell_filename(arch: str, shape: str, multi_pod: bool, label: str) -> str:
    mesh = "multi" if multi_pod else "single"
    return f"{arch}__{shape}__{mesh}__{label}.json"


def _run_all(args) -> int:
    from repro.configs import ARCHS, SHAPES, shape_applicable

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    failures = 0
    cells = [
        (a, s)
        for a in ARCHS
        for s in SHAPES
    ]
    for arch, shape in cells:
        fname = out / _cell_filename(arch, shape, args.multi_pod, args.label)
        if fname.exists() and not args.force:
            print(f"skip (cached): {fname.name}")
            continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape,
            "--out", str(out), "--label", args.label,
        ]
        if args.multi_pod:
            cmd.append("--multi-pod")
        if args.seq_shard:
            cmd.append("--seq-shard")
        if args.remat:
            cmd += ["--remat", args.remat]
        if args.rules != "baseline":
            cmd += ["--rules", args.rules]
        print(f"=== {arch} x {shape} ({'multi' if args.multi_pod else 'single'}) ===",
              flush=True)
        proc = subprocess.run(cmd, timeout=args.timeout)
        if proc.returncode != 0:
            failures += 1
            fname.write_text(json.dumps({
                "arch": arch, "shape": shape,
                "mesh": "2x16x16" if args.multi_pod else "16x16",
                "label": args.label, "status": "error",
                "error": f"subprocess exited {proc.returncode}",
            }, indent=1))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--rules", default="baseline")
    ap.add_argument("--moe-chunks", type=int, default=1)
    ap.add_argument("--loss-chunks", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--no-head-pad", action="store_true")
    ap.add_argument("--legacy-cache", action="store_true")
    ap.add_argument("--label", default="baseline")
    ap.add_argument("--out", default=str(_DEF_OUT))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args(argv)

    if args.all:
        return _run_all(args)

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")

    try:
        result = run_cell(
            args.arch, args.shape,
            multi_pod=args.multi_pod, seq_shard=args.seq_shard,
            remat=args.remat, rules=args.rules, label=args.label,
            moe_chunks=args.moe_chunks,
            loss_chunks=args.loss_chunks,
            grad_accum=args.grad_accum,
            head_padding=not args.no_head_pad,
            cache_seq_shard=not args.legacy_cache,
        )
    except Exception as e:  # record failures as data, they are bugs to fix
        import traceback

        result = {
            "arch": args.arch, "shape": args.shape,
            "mesh": "2x16x16" if args.multi_pod else "16x16",
            "label": args.label, "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    fname = out / _cell_filename(args.arch, args.shape, args.multi_pod, args.label)
    fname.write_text(json.dumps(result, indent=1))
    _print_summary(result)
    return 0 if result["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
