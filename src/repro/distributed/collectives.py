"""Interconnect-tiered collectives — the vendor-MPI swap, in shard_map.

The paper's MPI leg swaps the container's generic MPI for the host library
that knows the fabric (Aries/InfiniBand).  On a TPU multi-pod the fabric
has two tiers: ICI inside a pod (~50 GB/s/link) and DCN between pods
(~25 Gbit/s/host).  The *reference* collective is a flat all-reduce over
every DP axis; the *native* collective is hierarchical:

    reduce-scatter over ICI (data axis)        1/N-sized shards
    all-reduce over DCN (pod axis) on shards   cross-pod bytes / N
    all-gather over ICI (data axis)

which moves (pod-1)/pod * bytes/N over the thin DCN pipe instead of the
whole tensor — the textbook two-level schedule.  Both are registered as
implementations of the logical `grad_allreduce` op; the runtime swaps
exactly like it swaps kernels (requires_feature="hierarchical_collectives").

Optional int8 gradient compression (error feedback kept by the caller)
applies to the DCN leg only.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.quant import compress_int8

__all__ = [
    "compat_shard_map",
    "flat_grad_allreduce",
    "hierarchical_grad_allreduce",
    "make_grad_sync",
]


def compat_shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """shard_map across jax versions: top-level `jax.shard_map` (with
    ``check_vma``) where it exists, else the 0.4.x
    ``jax.experimental.shard_map`` (whose equivalent knob is ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def _pmean_tree(tree: Any, axes) -> Any:
    return jax.tree.map(lambda g: jax.lax.pmean(g, axes), tree)


def _axis_size(axis_name: str):
    """jax.lax.axis_size where it exists (newer jax); psum(1) is the
    version-agnostic spelling of the same quantity inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def flat_grad_allreduce(grads: Any, *, data_axis: str = "data",
                        pod_axis: str | None = None) -> Any:
    """Reference: one flat pmean over all DP axes (what the bundle ships)."""
    axes = (pod_axis, data_axis) if pod_axis else (data_axis,)
    return _pmean_tree(grads, axes)


# the DCN gradient compressor now lives in the shared quant module so
# the serving kernels and the checkpoint schema quantize with the same
# numerics the conformance grid pins; kept under its old private name
# for the call below and existing importers
_compress_int8 = compress_int8


def hierarchical_grad_allreduce(
    grads: Any,
    *,
    data_axis: str = "data",
    pod_axis: str | None = "pod",
    compress_dcn: bool = False,
) -> Any:
    """Native: ICI reduce-scatter -> DCN all-reduce on shards -> ICI
    all-gather.  Falls back to flat pmean when there is no pod axis."""
    if pod_axis is None:
        return _pmean_tree(grads, (data_axis,))

    def one(g: jnp.ndarray) -> jnp.ndarray:
        flat = g.reshape(-1)
        n = _axis_size(data_axis)
        pad = (-flat.size) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        # ICI: reduce-scatter over the data axis -> 1/n shard per device
        shard = jax.lax.psum_scatter(flat, data_axis, scatter_dimension=0, tiled=True)
        # DCN: all-reduce the small shard across pods (optionally int8)
        if compress_dcn:
            q, scale = _compress_int8(shard)
            qsum = jax.lax.psum(q.astype(jnp.int32), pod_axis)
            smax = jax.lax.pmax(scale, pod_axis)   # shared conservative scale
            shard = (qsum.astype(jnp.float32) * smax).astype(shard.dtype)
        else:
            shard = jax.lax.psum(shard, pod_axis)
        # ICI: all-gather the reduced shards back
        full = jax.lax.all_gather(shard, data_axis, axis=0, tiled=True)
        # sum -> mean over the full DP group
        total = _axis_size(data_axis) * _axis_size(pod_axis)
        return (full[: g.size].reshape(g.shape) / total).astype(g.dtype)

    return jax.tree.map(one, grads)


def make_grad_sync(
    mesh: jax.sharding.Mesh,
    *,
    native: bool,
    compress_dcn: bool = False,
    data_axis: str = "data",
    pod_axis: str = "pod",
):
    """Build the grad-sync callable for shard_map-style DP training loops.

    Used by tests/benchmarks to compare the two schedules numerically; the
    pjit train path gets the same effect from XLA's partitioner, with the
    schedule choice recorded in the lowered HLO (see benchmarks/table34).
    """
    has_pod = pod_axis in mesh.axis_names
    if native:
        return functools.partial(
            hierarchical_grad_allreduce,
            data_axis=data_axis,
            pod_axis=pod_axis if has_pod else None,
            compress_dcn=compress_dcn,
        )
    return functools.partial(
        flat_grad_allreduce,
        data_axis=data_axis,
        pod_axis=pod_axis if has_pod else None,
    )


def collective_specs(mesh: jax.sharding.Mesh):
    """in/out specs for running grad sync under shard_map on a grads tree
    that is replicated over DP axes and sharded over 'model'."""
    del mesh
    return P(), P()
