"""Logical-axis -> mesh-axis sharding rules (divisibility-checked).

The schema declares *logical* axes ("heads", "ff", "vocab", "experts", ...);
the deployment injects the mapping to *mesh* axes.  This is the same
separation the paper enforces between the hardware-agnostic image and the
site configuration: the bundle never names a mesh axis.

Rules are an ordered preference list.  For each parameter leaf we walk the
rules; an assignment is taken iff the logical axis occurs in the leaf, the
mesh axis (or axis tuple) exists, is unused so far on this leaf, and the
dimension is divisible by the axis size.  Non-divisible dims simply fall
through to the next rule — whisper's 8 heads on a 16-way model axis shard
by head_dim instead, published dims never force padding.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.schema import LeafSpec, map_leaves

__all__ = [
    "ShardingRules",
    "BASELINE_RULES",
    "param_specs",
    "param_shardings",
    "batch_spec",
    "cache_specs",
    "mesh_axis_sizes",
]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Ordered (logical_axis, mesh_axes) preferences.

    mesh_axes is a tuple: all its axes are assigned to the dim together
    (divisibility over the product), e.g. ("pod", "data") for FSDP storage.
    """

    rules: tuple[tuple[str, tuple[str, ...]], ...]

    def with_override(self, *pairs: tuple[str, tuple[str, ...]]) -> "ShardingRules":
        keys = {p[0] for p in pairs}
        kept = tuple(r for r in self.rules if r[0] not in keys)
        return ShardingRules(tuple(pairs) + kept)


# Paper-faithful baseline: TP on the parallel dims, FSDP storage over the
# DP axes for the big stacks (experts / embed).
BASELINE_RULES = ShardingRules(
    (
        ("experts", ("data",)),
        ("heads", ("model",)),
        ("kv_heads", ("model",)),
        ("ff", ("model",)),
        ("vocab", ("model",)),
        ("ssm_inner", ("model",)),
        ("ssm_heads", ("model",)),
        ("head_dim", ("model",)),
        ("embed", ("pod", "data")),
    )
)


def mesh_axis_sizes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _leaf_spec(leaf: LeafSpec, rules: ShardingRules, sizes: dict[str, int]) -> P:
    assignment: list = [None] * len(leaf.shape)
    used_mesh: set[str] = set()
    for logical, mesh_axes in rules.rules:
        axes = tuple(a for a in mesh_axes if a in sizes)
        if not axes or any(a in used_mesh for a in axes):
            continue
        prod = int(np.prod([sizes[a] for a in axes]))
        for dim, name in enumerate(leaf.axes):
            if name != logical or assignment[dim] is not None:
                continue
            if leaf.shape[dim] % prod == 0 and prod > 1:
                assignment[dim] = axes if len(axes) > 1 else axes[0]
                used_mesh.update(axes)
            break  # only the first matching dim per rule
    return P(*assignment)


def param_specs(schema: dict, rules: ShardingRules, mesh: jax.sharding.Mesh) -> dict:
    sizes = mesh_axis_sizes(mesh)
    return map_leaves(lambda _, s: _leaf_spec(s, rules, sizes), schema)


def param_shardings(schema: dict, rules: ShardingRules, mesh: jax.sharding.Mesh) -> dict:
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs(schema, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(
    batch_size: int, mesh: jax.sharding.Mesh,
    batch_axes: Sequence[str] = ("pod", "data"),
) -> tuple:
    """Largest prefix of batch_axes whose product divides batch_size."""
    sizes = mesh_axis_sizes(mesh)
    chosen: list[str] = []
    prod = 1
    for a in batch_axes:
        if a not in sizes:
            continue
        if batch_size % (prod * sizes[a]) == 0:
            chosen.append(a)
            prod *= sizes[a]
    return tuple(chosen)


def cache_specs(cache_tree: dict, batch: int, mesh: jax.sharding.Mesh,
                *, seq_shard: bool = True) -> dict:
    """Specs for KV/SSM caches.

    k/v preference order: batch over DP axes; kv_heads over model when
    divisible, else the SEQUENCE over model (decode attention over an
    S-sharded cache reduces with a tiny logsumexp psum, whereas a
    head_dim-sharded cache makes every score einsum contract the sharded
    dim — measured as multi-GB fp32 all-reduces); head_dim only as the
    last resort.  Unshardable batch (long_500k B=1) pushes the DP axes
    onto the sequence too."""
    sizes = mesh_axis_sizes(mesh)
    baxes = batch_spec(batch, mesh)
    m = "model" if "model" in sizes else None

    def spec_for(path: str, x) -> P:
        shape = x.shape
        leaf_kind = path.rsplit("/", 1)[-1]
        if leaf_kind in ("k", "v", "ck", "cv"):      # (nb, B, S, KV, dh)
            s, kv, dh = shape[2], shape[3], shape[4]
            head_assign = None
            dh_assign = None
            seq_pool: list[str] = []
            if not baxes:
                seq_pool += [a for a in ("pod", "data") if a in sizes]
            if m and kv % sizes[m] == 0:
                head_assign = m
            elif m and seq_shard:
                seq_pool.append(m)
            # largest prefix of seq_pool whose product divides S
            seq_axes: list[str] = []
            prod = 1
            for a in seq_pool:
                if s % (prod * sizes[a]) == 0:
                    seq_axes.append(a)
                    prod *= sizes[a]
            if m and head_assign is None and m not in seq_axes and dh % sizes[m] == 0:
                dh_assign = m
            seq_assign = tuple(seq_axes) if seq_axes else None
            return P(None, baxes or None, seq_assign, head_assign, dh_assign)
        if leaf_kind == "state":                      # (nb, B, H, N, P)
            h = shape[2]
            ha = m if (m and h % sizes[m] == 0) else None
            return P(None, baxes or None, ha, None, None)
        if leaf_kind == "conv":                       # (nb, B, K-1, Din)
            din = shape[3]
            da = m if (m and din % sizes[m] == 0) else None
            return P(None, baxes or None, None, da)
        return P()

    out = {}
    for pk, entry in cache_tree.items():
        out[pk] = {k: spec_for(f"{pk}/{k}", v) for k, v in entry.items()}
    return out
