"""GPipe-style pipeline parallelism over an optional 'pipe' mesh axis.

Off in the assigned production mesh (data x model), but a first-class
feature: at >4k chips a deployment trades DP ways for stages.  The
schedule is the classic shard_map loop: every tick each stage computes its
microbatch and collective-permutes the activation to the next stage;
bubbles compute garbage that is masked at collection (M + S - 1 ticks for
M microbatches over S stages, bubble fraction (S-1)/(M+S-1)).

`pipeline_apply` is schedule-only: it takes an arbitrary per-stage
function, so tests verify it against the sequential composition.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.collectives import compat_shard_map

__all__ = ["pipeline_apply"]


def pipeline_apply(
    stage_fn: Callable,            # (stage_params, x (mb, ...)) -> y (mb, ...)
    stage_params,                  # pytree, leaves stacked (S, ...) sharded on pipe
    x,                             # (M, mb, ...) microbatched input
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "pipe",
):
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    m = x.shape[0]
    assert m >= 1

    def per_stage(params_local, x_local):
        # params_local: (1, ...) leaves — this stage's slice
        params_here = jax.tree.map(lambda p: p[0], params_local)
        s_idx = jax.lax.axis_index(axis)
        ticks = m + n_stages - 1

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 injects microbatch t (clamped); others use the buffer
            inject = x_local[jnp.minimum(t, m - 1)]
            x_in = jnp.where(s_idx == 0, inject, buf)
            y = stage_fn(params_here, x_in)
            # pass activations downstream (stage i -> i+1)
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            buf_next = jax.lax.ppermute(y, axis, perm)
            # last stage collects tick t as microbatch t-(S-1)
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (s_idx == n_stages - 1)
            outputs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0
                ),
                lambda o: o,
                outputs,
            )
            return (buf_next, outputs), None

        buf0 = jnp.zeros_like(x_local[0])
        out0 = jnp.zeros_like(x_local)
        (_, outputs), _ = jax.lax.scan(
            tick, (buf0, out0), jnp.arange(ticks)
        )
        # broadcast the last stage's outputs (all other stages hold zeros)
        return jax.lax.psum(outputs, axis)

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)
    return compat_shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)
