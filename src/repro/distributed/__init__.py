"""Distribution layer: sharding rules, tiered collectives, pipeline."""

from repro.distributed.collectives import (
    compat_shard_map,
    flat_grad_allreduce,
    hierarchical_grad_allreduce,
    make_grad_sync,
)
from repro.distributed.pipeline import pipeline_apply
from repro.distributed.sharding import (
    BASELINE_RULES,
    ShardingRules,
    batch_spec,
    cache_specs,
    mesh_axis_sizes,
    param_shardings,
    param_specs,
)

__all__ = [
    "compat_shard_map",
    "flat_grad_allreduce", "hierarchical_grad_allreduce", "make_grad_sync",
    "pipeline_apply",
    "BASELINE_RULES", "ShardingRules", "batch_spec", "cache_specs",
    "mesh_axis_sizes", "param_shardings", "param_specs",
]
