"""Environment-triggered resource activation (the CUDA_VISIBLE_DEVICES leg).

The paper (§IV-A) activates GPU support iff ``CUDA_VISIBLE_DEVICES`` holds a
valid comma-separated device list; the workload manager (SLURM GRES) is the
usual writer.  Invalid or absent values deactivate the feature silently.
Inside the container devices are renumbered from 0 regardless of the host
ids, so single-GPU images run unmodified on multi-GPU hosts.

`repro` mirrors each behaviour:

  REPRO_VISIBLE_DEVICES   comma-separated physical device indices (or 'all').
                          Valid value  -> accelerator binding activates, the
                          selected devices become logical devices 0..N-1.
                          Invalid/absent -> feature off, single-device laptop
                          semantics (reference ops, trivial mesh).
  REPRO_PLATFORM          explicit site selection (overrides detection),
                          the analogue of the sysadmin's shifter config.
  REPRO_NATIVE_OPS        "1"/"0": default for the --native-ops flag (--mpi).
  REPRO_AUTOTUNE          "1"/"0": default for the deploy(autotune=) flag —
                          resolve kernel block configs from the site's
                          tuning cache (searching on first miss).
  REPRO_TUNING_CACHE      path of the site-local tuning cache JSON
                          (consumed by repro.tuning.resolve_cache_path).
  REPRO_PROFILE           "1"/"0": default for the deploy(profile=) flag —
                          capture every op invocation's shape bucket/dtype
                          into the site workload profile (live geometry
                          capture for tune-on-real-traffic).
  REPRO_WORKLOAD_PROFILE  path of the workload profile JSON (consumed by
                          repro.tuning.resolve_profile_path).
  REPRO_SEARCH_BUDGET     non-negative integer: cap on how many tuning
                          searches one deploy may pay.  With a workload
                          profile present the budget is spent hottest-op
                          first (profile-driven autotune_ops selection);
                          absent/invalid values mean unlimited.
  REPRO_TUNING_MAX_ENTRIES  positive integer: default for
                          deploy(max_tuned_entries=) — per-op cap on the
                          geometry-dispatch table.  Each op binds at most
                          K buckets (hottest first); cached entries
                          beyond the cap are LRU-evicted under pressure
                          ("cache-evicted-lru" in the SwapReport).
                          Absent/invalid values mean unbounded (the
                          append-only pre-lifecycle behaviour).
  REPRO_TUNING_MAX_BYTES  positive integer: byte-denominated cap on the
                          site tuning cache's serialized size (the
                          ``entry_bytes`` accounting from the lifecycle
                          layer).  Enforced alongside the entry-count
                          cap by ``TuningCache.compact``/``save`` and the
                          ``warm --compact`` GC: coldest entries are
                          evicted first until the file fits the budget.
                          Absent/invalid values mean unbounded.
  REPRO_TUNING_BUNDLE     path of a portable tuning bundle (see
                          repro.tuning.bundle): default for
                          deploy(tuning_bundle=) — auto-imported into the
                          site cache before binding, with every entry
                          revalidated against THIS platform (feasible ->
                          first-class, infeasible -> demoted candidate,
                          corrupt/ABI-incompatible -> rejected wholesale,
                          leaving the cache untouched).  Absent means no
                          import.
"""

from __future__ import annotations

import dataclasses
import os
import re
from typing import Sequence

import jax

from repro.core.platform import PLATFORMS, Platform, detect_platform

__all__ = [
    "VisibleDevices",
    "parse_visible_devices",
    "select_devices",
    "resolve_platform",
    "native_ops_default",
    "autotune_default",
    "profile_default",
    "search_budget_default",
    "tuning_max_entries_default",
    "tuning_max_bytes_default",
    "tuning_bundle_default",
    "ENV_VISIBLE",
    "ENV_PLATFORM",
    "ENV_NATIVE_OPS",
    "ENV_AUTOTUNE",
    "ENV_PROFILE",
    "ENV_SEARCH_BUDGET",
    "ENV_TUNING_MAX_ENTRIES",
    "ENV_TUNING_MAX_BYTES",
    "ENV_TUNING_BUNDLE",
]

ENV_VISIBLE = "REPRO_VISIBLE_DEVICES"
ENV_PLATFORM = "REPRO_PLATFORM"
ENV_NATIVE_OPS = "REPRO_NATIVE_OPS"
ENV_AUTOTUNE = "REPRO_AUTOTUNE"
ENV_PROFILE = "REPRO_PROFILE"
ENV_SEARCH_BUDGET = "REPRO_SEARCH_BUDGET"
ENV_TUNING_MAX_ENTRIES = "REPRO_TUNING_MAX_ENTRIES"
ENV_TUNING_MAX_BYTES = "REPRO_TUNING_MAX_BYTES"
ENV_TUNING_BUNDLE = "REPRO_TUNING_BUNDLE"

_INT_LIST_RE = re.compile(r"^\s*\d+\s*(,\s*\d+\s*)*$")


@dataclasses.dataclass(frozen=True)
class VisibleDevices:
    """Outcome of parsing REPRO_VISIBLE_DEVICES.

    ``active`` is the GPU-support trigger: False replicates Shifter's
    "do not trigger the GPU support procedure" path.
    """

    active: bool
    indices: tuple[int, ...] | None  # None == 'all'
    raw: str | None = None


def parse_visible_devices(value: str | None) -> VisibleDevices:
    """Validate the trigger variable exactly as §IV-A.1 prescribes.

    A valid value is 'all' or a comma-separated list of non-negative
    integers with no duplicates.  Anything else (empty string, negatives,
    junk) deactivates the feature rather than erroring — a job scheduled
    without accelerators must still run.
    """
    if value is None:
        return VisibleDevices(active=False, indices=None, raw=None)
    text = value.strip()
    if text.lower() == "all":
        return VisibleDevices(active=True, indices=None, raw=value)
    if not _INT_LIST_RE.match(text):
        return VisibleDevices(active=False, indices=None, raw=value)
    idx = tuple(int(t) for t in text.split(","))
    if len(set(idx)) != len(idx):
        return VisibleDevices(active=False, indices=None, raw=value)
    return VisibleDevices(active=True, indices=idx, raw=value)


def select_devices(
    vis: VisibleDevices, devices: Sequence[jax.Device] | None = None
) -> list[jax.Device]:
    """Renumber physical devices into the logical 0..N-1 space.

    Mirrors §IV-A.3: with CUDA_VISIBLE_DEVICES=2 the container addresses
    that device as 0.  Out-of-range indices are dropped (the scheduler may
    describe a superset host); order is preserved so index 0 is the first
    *visible* device, not the first physical one.
    """
    devices = list(devices if devices is not None else jax.devices())
    if not vis.active or vis.indices is None:
        return devices
    return [devices[i] for i in vis.indices if 0 <= i < len(devices)]


def resolve_platform(
    env: dict[str, str] | None = None,
    devices: Sequence[jax.Device] | None = None,
) -> Platform:
    """REPRO_PLATFORM override, else device-based detection."""
    env = os.environ if env is None else env
    name = env.get(ENV_PLATFORM, "").strip()
    if name:
        if name not in PLATFORMS:
            raise KeyError(
                f"{ENV_PLATFORM}={name!r} names no configured platform; "
                f"known: {sorted(PLATFORMS)}"
            )
        return PLATFORMS[name]
    return detect_platform(devices)


def native_ops_default(env: dict[str, str] | None = None) -> bool:
    env = os.environ if env is None else env
    return env.get(ENV_NATIVE_OPS, "0").strip() == "1"


def autotune_default(env: dict[str, str] | None = None) -> bool:
    env = os.environ if env is None else env
    return env.get(ENV_AUTOTUNE, "0").strip() == "1"


def profile_default(env: dict[str, str] | None = None) -> bool:
    env = os.environ if env is None else env
    return env.get(ENV_PROFILE, "0").strip() == "1"


def search_budget_default(env: dict[str, str] | None = None) -> int | None:
    """REPRO_SEARCH_BUDGET as a non-negative int, else None (unlimited).

    Invalid values deactivate the cap rather than erroring, like every
    other trigger variable here: a malformed budget must not block a
    deployment that would otherwise run.
    """
    env = os.environ if env is None else env
    text = str(env.get(ENV_SEARCH_BUDGET, "")).strip()
    if not text:
        return None
    try:
        value = int(text)
    except ValueError:
        return None
    return value if value >= 0 else None


def tuning_max_entries_default(env: dict[str, str] | None = None) -> int | None:
    """REPRO_TUNING_MAX_ENTRIES as a positive int, else None (unbounded).

    Zero is treated as invalid, not as "no tuning state at all": a cap of
    0 would evict every warmed bucket at bind time, which no deployment
    can want — like every trigger variable here, a nonsensical value
    deactivates the feature instead of erroring or degrading service.
    """
    env = os.environ if env is None else env
    text = str(env.get(ENV_TUNING_MAX_ENTRIES, "")).strip()
    if not text:
        return None
    try:
        value = int(text)
    except ValueError:
        return None
    return value if value > 0 else None


def tuning_max_bytes_default(env: dict[str, str] | None = None) -> int | None:
    """REPRO_TUNING_MAX_BYTES as a positive int, else None (unbounded).

    Zero is treated as invalid for the same reason as the entry cap: a
    0-byte budget would evict every warmed entry, which no site can
    want — a nonsensical value deactivates the feature instead of
    erroring or degrading service.
    """
    env = os.environ if env is None else env
    text = str(env.get(ENV_TUNING_MAX_BYTES, "")).strip()
    if not text:
        return None
    try:
        value = int(text)
    except ValueError:
        return None
    return value if value > 0 else None


def tuning_bundle_default(env: dict[str, str] | None = None) -> str | None:
    """REPRO_TUNING_BUNDLE as a path string, else None (no auto-import).

    Existence is NOT checked here: a missing/corrupt bundle is diagnosed
    (and degraded to a warning) by the deploy-time import, which is the
    stage that can say *why* the artifact is unusable.
    """
    env = os.environ if env is None else env
    text = str(env.get(ENV_TUNING_BUNDLE, "")).strip()
    return text or None
