"""Runtime — the Shifter Runtime, staged exactly as the paper's §III-A.

    pull/reformat        -> Gateway (separate component, as in Fig. 1)
    prepare environment  -> resolve platform, select+renumber devices,
                            build the mesh, swap ops (native support),
                            specialize kernels from the site tuning
                            cache (autotune) and/or wrap the binding
                            for live workload capture (profile)
    chroot jail          -> Container object: the program sees ONLY the
                            frozen OpBinding and merged env — never the
                            registry or host environment directly
    drop privileges      -> freeze the registry (no rebinding mid-run)
    export env variables -> bundle env ∪ selected host env (host wins on
                            the site-specific allowlist, like Shifter's
                            config-driven variable sourcing)
    execute              -> jit'd step functions run under the mesh
    cleanup              -> thaw registry, release the container

GPU-support trigger semantics (§IV-A) are preserved: accelerator binding
activates only on a *valid* REPRO_VISIBLE_DEVICES; otherwise the container
still runs, on the default (laptop) resources.
"""

from __future__ import annotations

import dataclasses
import logging
import os
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax

from repro.core.bundle import Bundle
from repro.core.env import (
    ENV_VISIBLE,
    autotune_default,
    native_ops_default,
    parse_visible_devices,
    profile_default,
    resolve_platform,
    search_budget_default,
    select_devices,
    tuning_bundle_default,
    tuning_max_bytes_default,
    tuning_max_entries_default,
)
from repro.core.platform import Platform
from repro.core.registry import OpBinding, OpRegistry, global_registry

__all__ = ["Runtime", "Container", "DeploymentError"]

log = logging.getLogger("repro.runtime")

# Host variables a container inherits (Shifter: "selected variables from the
# host system are also added", per site configuration).
_HOST_ENV_ALLOWLIST = (ENV_VISIBLE, "REPRO_PLATFORM", "REPRO_CHECKPOINT_DIR",
                       "REPRO_COMPILE_CACHE", "REPRO_AUTOTUNE",
                       "REPRO_TUNING_CACHE", "REPRO_PROFILE",
                       "REPRO_WORKLOAD_PROFILE", "REPRO_SEARCH_BUDGET",
                       "REPRO_TUNING_MAX_ENTRIES", "REPRO_TUNING_MAX_BYTES",
                       "REPRO_TUNING_BUNDLE")


class DeploymentError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class Container:
    """A deployed program: the chroot'd view of the world.

    Everything the program may touch is here — ops come exclusively from
    ``binding`` (the bind-mounted libraries), resources from ``mesh``, and
    configuration from ``env``/``bundle``.
    """

    bundle: Bundle
    platform: Platform
    mesh: jax.sharding.Mesh
    binding: OpBinding
    env: Mapping[str, str]
    native_ops: bool
    autotune: bool = False
    profile: bool = False
    workload: Any = None   # tuning.WorkloadProfile capturing this
    # container's op geometries; None unless profiling is on.  Persisted
    # by Runtime.cleanup().
    tuning_imports: Any = None   # tuning.bundle.ImportReport of the
    # tuning-bundle import that ran before binding; None when no bundle
    # was given (or its import was rejected — the rejection is logged).

    @property
    def devices(self) -> tuple[jax.Device, ...]:
        return tuple(self.mesh.devices.flat)

    def describe(self) -> str:
        head = (
            f"container {self.bundle.reference} (digest {self.bundle.digest})\n"
            f"  platform: {self.platform.name} ({self.platform.description})\n"
            f"  mesh: shape={dict(zip(self.mesh.axis_names, self.mesh.devices.shape))} "
            f"devices={self.mesh.devices.size}\n"
            f"  native ops: {'enabled' if self.native_ops else 'disabled'}"
            f" | autotune: {'on' if self.autotune else 'off'}"
            f" | profile: {'on' if self.profile else 'off'}\n"
        )
        return head + self.binding.describe()


class Runtime:
    """Deploys bundles onto a site.  One Runtime per process, like `shifter`.

    Args:
      registry: the op registry to bind from; defaults to the process
        global one (populated by ``repro.kernels.ops.register_all``).
      host_env: the site environment consulted for every ``REPRO_*``
        trigger variable (see core/env.py) and forwarded to the
        container through the allowlist; defaults to ``os.environ``.
        Tests pass an explicit dict for hermeticity.

    One container may be active at a time; ``deploy`` raises
    DeploymentError if called again before ``cleanup``.
    """

    def __init__(
        self,
        registry: OpRegistry | None = None,
        host_env: Mapping[str, str] | None = None,
    ) -> None:
        self.registry = registry if registry is not None else global_registry
        self.host_env = dict(os.environ if host_env is None else host_env)
        self._active: Container | None = None

    # ------------------------------------------------------------------ #
    def deploy(
        self,
        bundle: Bundle,
        *,
        native_ops: bool | None = None,
        platform: Platform | None = None,
        mesh: jax.sharding.Mesh | None = None,
        devices: Sequence[jax.Device] | None = None,
        extra_ops: Iterable[str] = (),
        freeze: bool = True,
        autotune: bool | None = None,
        autotune_ops: Iterable[str] | None = None,
        autotune_top_k: int = 3,
        search_budget: int | None = None,
        max_tuned_entries: int | None = None,
        tuning_bundle: str | os.PathLike | None = None,
        profile: bool | None = None,
    ) -> Container:
        """Run the preparation stages and hand back the executable Container.

        Args:
          native_ops: the ``--mpi`` flag (None -> REPRO_NATIVE_OPS env
            default); ``mesh`` may be injected by launchers that already
            built the production mesh (dryrun/train), otherwise one is
            derived from the platform topology and the visible devices.
          autotune: (None -> REPRO_AUTOTUNE env default) opts this
            deployment into the site tuning cache: bound native kernels
            get their block configs from REPRO_TUNING_CACHE, searching
            (and persisting the winner) on a miss.  When the site also
            has a workload profile (REPRO_WORKLOAD_PROFILE) with recorded
            traffic, the binding is *geometry-dispatched*: every op's
            top-K observed buckets (plus any further warmed cache
            entries) are resolved into a per-geometry config table, and
            each call picks its entry at trace time — a
            ``repro.tuning.warm``-ed cache replays a shape-polymorphic
            deployment with zero misses and zero searches.  Entries
            tuned against an older kernel ABI revision are expired and
            re-searched, with the eviction noted in the SwapReport
            ("cache-expired-searched").
          autotune_ops: restricts which ops may pay the search cost;
            cache hits and default fallbacks always apply and are
            recorded per-op in the binding's SwapReports.  When None and
            the site has recorded traffic, selection is profile-driven:
            ops bind hottest-first so any search budget is spent where
            traffic actually goes, with each op's rank recorded in its
            SwapReport (``search_rank``).
          autotune_top_k: recorded geometries per op entering the
            dispatch table (mirrors ``repro.tuning.warm --top``).
          search_budget: (None -> REPRO_SEARCH_BUDGET env default) cap on
            how many searches this deploy may pay; misses beyond it bind
            the platform default ("search-budget-exhausted").
          max_tuned_entries: (None -> REPRO_TUNING_MAX_ENTRIES env
            default) per-op cap on the geometry-dispatch table — the
            bounded tuning-state mode.  Each op binds at most this many
            buckets, hottest first; cached entries beyond the cap are
            LRU-evicted under pressure (tombstoned, persisted at flush)
            and surfaced as "cache-evicted-lru" in the SwapReport, so a
            warmed redeploy over more recorded buckets than the cap
            provably keeps exactly the K hottest.  bf16 traffic landing
            on a capped table that only holds fp32 buckets dispatches
            via the "near-dtype" borrow instead of the shipped default.
          tuning_bundle: (None -> REPRO_TUNING_BUNDLE env default, then
            the run bundle's own ``tuning_bundle`` reference) path of a
            portable tuning bundle (repro.tuning.bundle) to auto-import
            into the site cache BEFORE binding.  Every entry is
            revalidated against this platform: feasible entries land
            first-class and bind as "bundle-imported" geometries;
            structurally-matched-but-infeasible ones are demoted to
            penalized dispatch candidates ("bundle-demoted", never bound
            raw); structurally foreign buckets are rejected per entry
            ("bundle-rejected" in the SwapReport).  A corrupt, tampered,
            wrong-schema, or ABI-major-incompatible artifact is rejected
            wholesale — the site cache stays byte-identical and the
            deployment continues cold with a warning (the CLI import, by
            contrast, exits non-zero).
          profile: (None -> REPRO_PROFILE env default) captures every op
            invocation's shape bucket + dtype into the site workload
            profile (under jit: once per compiled geometry, at trace
            time).  The profile is persisted by ``cleanup()``; an
            unwritable profile path degrades to a warning, never an
            error.

        Raises DeploymentError when the site cannot satisfy a bundle-
        required ABI at all, no devices are visible, or a container is
        already active in this Runtime.
        """
        if self._active is not None:
            raise DeploymentError(
                "a container is already running in this Runtime; cleanup() first"
            )

        # -- stage: prepare software environment ---------------------------
        if native_ops is None:
            native_ops = native_ops_default(self.host_env)
        vis = parse_visible_devices(self.host_env.get(ENV_VISIBLE))
        if platform is None:
            platform = resolve_platform(self.host_env, devices)
        if mesh is None:
            mesh = self._make_mesh(platform, vis, devices)

        # ABI verification against the bundle's requirements: the runtime
        # refuses deployment if the site cannot satisfy a required contract
        # at all (no reference either) — a missing libmpi, not a bad swap.
        required = bundle.required_abis()
        for op, want in required.items():
            try:
                decl = self.registry.decl(op)
            except KeyError as e:
                raise DeploymentError(f"site provides no op '{op}'") from e
            why = want.why_incompatible(decl.abi)
            if why is not None:
                raise DeploymentError(
                    f"bundle requires {want} but site declares {decl.abi}: {why}"
                )

        ops = list(required) + [o for o in extra_ops if o not in required]

        # -- stage: workload capture (live geometry profiling) ---------------
        if profile is None:
            profile = profile_default(self.host_env)
        workload = None
        if profile:
            from repro.tuning import WorkloadProfile, resolve_profile_path

            profile_path = resolve_profile_path(self.host_env)
            workload = WorkloadProfile.load(profile_path)
            log.info("profiling on: workload profile %s (%d geometries)",
                     profile_path, len(workload))

        # -- stage: tuning-bundle import (portable site artifacts) -----------
        # The shipped artifact lands in the site cache before the binding
        # reads it, so a laptop-warmed bundle turns a cold cluster deploy
        # into a zero-search one.  Rejections degrade to a warning: a bad
        # artifact must not kill a deployment that can still run cold.
        if tuning_bundle is None:
            tuning_bundle = tuning_bundle_default(self.host_env)
        if tuning_bundle is None:
            tuning_bundle = bundle.tuning_bundle
        bundle_report = None
        if tuning_bundle:
            from repro.tuning import resolve_cache_path
            from repro.tuning.bundle import BundleFormatError, import_bundle

            try:
                bundle_report = import_bundle(
                    tuning_bundle,
                    cache_path=resolve_cache_path(self.host_env),
                    platform=platform, registry=self.registry,
                )
                log.info("tuning bundle %s: %s", tuning_bundle,
                         bundle_report.describe().splitlines()[0])
            except (BundleFormatError, OSError) as e:
                log.warning("tuning bundle %s rejected: %s (site cache "
                            "untouched; deploying cold)", tuning_bundle, e)

        # -- stage: site specialization (deferred kernel tuning) -------------
        if autotune is None:
            autotune = autotune_default(self.host_env)
        tuning_ctx = None
        if autotune:
            from repro.tuning import (
                TuningCache,
                TuningContext,
                WorkloadProfile,
                resolve_cache_path,
                resolve_profile_path,
            )

            cache_path = resolve_cache_path(self.host_env)
            # key tuning on observed traffic whenever the site has a
            # profile — captured by this deployment or a previous one
            tune_profile = workload
            if tune_profile is None:
                recorded = WorkloadProfile.load(resolve_profile_path(self.host_env))
                tune_profile = recorded if len(recorded) else None
            # expiry must compare against the ABI cache keys are written
            # under — the bound tunable native's, which may carry a newer
            # minor than the declaration
            current_abis = {}
            for op in ops:
                native = self.registry.decl(op).tunable_native(platform)
                if native is not None:
                    current_abis[op] = native.abi
            if search_budget is None:
                search_budget = search_budget_default(self.host_env)
            if max_tuned_entries is None:
                max_tuned_entries = tuning_max_entries_default(self.host_env)
            priority = None
            if autotune_ops is None and tune_profile is not None:
                # profile-driven selection: bind (and therefore search)
                # the hottest ops first, so a bounded search budget is
                # spent where traffic actually goes; unprofiled ops keep
                # their relative order after the hot ones
                totals = tune_profile.op_totals()
                hot = sorted((op for op in ops if totals.get(op)),
                             key=lambda o: (-totals[o], o))
                ops = hot + [op for op in ops if op not in set(hot)]
                priority = {op: i + 1 for i, op in enumerate(hot)}
            site_cache = TuningCache.load(cache_path)
            # byte-denominated bound on the cache FILE (distinct from the
            # per-op table cap below): enforced when the flush saves, so
            # one deploy cannot grow the site file past the site's budget
            site_cache.max_bytes = tuning_max_bytes_default(self.host_env)
            tuning_ctx = TuningContext(
                site_cache, platform,
                ops=autotune_ops if autotune_ops is None else set(autotune_ops),
                profile=tune_profile,
                current_abis=current_abis,
                top_k=autotune_top_k,
                search_budget=search_budget,
                priority=priority,
                max_entries=max_tuned_entries,
                bundle_report=bundle_report,
            )
            log.info("autotune on: cache %s (%d entries%s%s%s)",
                     cache_path, len(tuning_ctx.cache),
                     ", profile-keyed" if tune_profile is not None else "",
                     f", search budget {search_budget}"
                     if search_budget is not None else "",
                     f", table cap {max_tuned_entries}"
                     if max_tuned_entries is not None else "")

        binding = self.registry.bind(ops, platform, native=native_ops,
                                     freeze=freeze, tuning=tuning_ctx)
        if tuning_ctx is not None:
            tuning_ctx.flush()   # persist winners + expirations atomically
        if workload is not None:
            from repro.tuning import profiled_binding

            binding = profiled_binding(binding, workload)
        for r in binding.reports:
            log.info("bind %-18s %s", r.op, r.reason)

        # -- stage: export of environment variables -------------------------
        env = dict(bundle.env)
        for key in _HOST_ENV_ALLOWLIST:
            if key in self.host_env:
                env[key] = self.host_env[key]

        container = Container(
            bundle=bundle,
            platform=platform,
            mesh=mesh,
            binding=binding,
            env=env,
            native_ops=native_ops,
            autotune=autotune,
            profile=profile,
            workload=workload,
            tuning_imports=bundle_report,
        )
        self._active = container
        return container

    # ------------------------------------------------------------------ #
    def cleanup(self) -> None:
        """Release the container: persist the workload profile (if this
        deployment was capturing), thaw the registry, clear the jit caches.

        A profile that cannot be written is logged and dropped — losing
        observability data must never fail the workload that produced it.
        """
        if self._active is not None and self._active.workload is not None:
            workload = self._active.workload
            if workload.dirty:
                try:
                    path = workload.save()
                    log.info("workload profile persisted: %s (%d geometries)",
                             path, len(workload))
                except OSError as e:
                    log.warning("could not persist workload profile %s: %s",
                                workload.path, e)
        self._active = None
        self.registry.thaw()
        jax.clear_caches()

    # ------------------------------------------------------------------ #
    def _make_mesh(
        self,
        platform: Platform,
        vis,
        devices: Sequence[jax.Device] | None,
    ) -> jax.sharding.Mesh:
        """Build the execution mesh from the visible, renumbered devices.

        Mirrors §IV-A.3: logical coordinates always start at 0; the mesh is
        shaped by the platform topology, truncated to a prefix shape if
        fewer devices are visible (a container built for 1 GPU runs on a
        multi-GPU host and vice versa).
        """
        import numpy as np

        pool = select_devices(vis, devices)
        if not pool:
            raise DeploymentError("no visible devices after renumbering")
        want = platform.num_devices
        if len(pool) >= want:
            chosen = pool[:want]
            shape = platform.mesh_shape
            axes = platform.mesh_axes
        else:
            # degrade to a 1-D data mesh over what is actually visible
            chosen = pool
            shape = (len(pool),)
            axes = ("data",)
        arr = np.array(chosen, dtype=object).reshape(shape)
        return jax.sharding.Mesh(arr, axes)
