"""Gateway — the Image Gateway of the paper (§III, Fig. 1), for Bundles.

Responsibilities mirror the original:

  * **pull**: fetch a bundle (and its base chain) from a *registry*
    (a remote in production; a directory here), like `shifterimg pull`.
  * **flatten**: collapse the layer chain onto a single bundle — "all layers
    but the last one are discarded".
  * **convert**: write the flattened bundle into the site cache as one
    immutable blob keyed by digest — the squashfs-on-parallel-FS step.
    Every node of a job loads this single artifact (one metadata lookup)
    instead of re-resolving N layers (the Pynamic lesson).
  * **query/list**: `shifterimg images`.

The Gateway is the only component that touches the registry; the Runtime
only ever reads the local cache.
"""

from __future__ import annotations

import json
import logging
from pathlib import Path

from repro.core.bundle import Bundle, BundleError

__all__ = ["Gateway", "GatewayError"]

log = logging.getLogger("repro.gateway")

_MAX_LAYER_DEPTH = 16


class GatewayError(RuntimeError):
    pass


class Gateway:
    def __init__(self, registry_dir: Path | str, cache_dir: Path | str):
        self.registry_dir = Path(registry_dir)
        self.cache_dir = Path(cache_dir)
        self.cache_dir.mkdir(parents=True, exist_ok=True)
        (self.cache_dir / "tags").mkdir(exist_ok=True)

    # -- registry side (docker hub analogue) -------------------------------
    def push(self, bundle: Bundle) -> Path:
        """Publish a bundle to the registry (the build-workstation step)."""
        path = self.registry_dir / f"{bundle.name}__{bundle.tag}.json"
        return bundle.save(path)

    def _fetch(self, reference: str) -> Bundle:
        name, _, tag = reference.partition(":")
        tag = tag or "latest"
        path = self.registry_dir / f"{name}__{tag}.json"
        if not path.exists():
            raise GatewayError(f"registry has no bundle {reference!r}")
        return Bundle.load(path)

    # -- pull + flatten + convert -------------------------------------------
    def pull(self, reference: str) -> Bundle:
        """Pull a bundle, flatten its base chain, convert into the cache.

        Returns the flattened bundle.  Idempotent: a digest already in cache
        is reused (images are content-addressed).
        """
        chain: list[Bundle] = []
        ref = reference
        for _ in range(_MAX_LAYER_DEPTH):
            b = self._fetch(ref)
            chain.append(b)
            if b.base is None:
                break
            ref = b.base
        else:
            raise GatewayError(f"layer chain of {reference!r} exceeds {_MAX_LAYER_DEPTH}")

        flat = chain[-1]
        for child in reversed(chain[:-1]):
            flat = child.flatten_onto(flat)

        blob = self.cache_dir / f"{flat.digest}.bundle.json"
        if not blob.exists():
            flat.save(blob)
            log.info("gateway: converted %s -> %s", reference, blob.name)
        # tag file: mutable pointer, like the image tag listing
        tagfile = self.cache_dir / "tags" / f"{flat.name}__{flat.tag}"
        tagfile.write_text(flat.digest)
        return flat

    # -- runtime side ----------------------------------------------------------
    def lookup(self, reference: str) -> Bundle:
        """Resolve a pulled image from the local cache only (no registry I/O)."""
        name, _, tag = reference.partition(":")
        tagfile = self.cache_dir / "tags" / f"{name}__{tag or 'latest'}"
        if not tagfile.exists():
            raise GatewayError(
                f"image {reference!r} not in cache; run gateway.pull() first"
            )
        digest = tagfile.read_text().strip()
        return Bundle.load(self.cache_dir / f"{digest}.bundle.json")

    def images(self) -> list[dict[str, str]]:
        """`shifterimg images` — list cached, ready-to-run bundles."""
        out = []
        for tagfile in sorted((self.cache_dir / "tags").iterdir()):
            name, _, tag = tagfile.name.partition("__")
            out.append({"name": name, "tag": tag, "digest": tagfile.read_text().strip()})
        return out

    def gc(self) -> int:
        """Drop cache blobs no tag points at; returns count removed."""
        live = {t.read_text().strip() for t in (self.cache_dir / "tags").iterdir()}
        removed = 0
        for blob in self.cache_dir.glob("*.bundle.json"):
            if blob.name.split(".")[0] not in live:
                blob.unlink()
                removed += 1
        return removed
