"""Op ABI: the libtool-ABI-string analogue for JAX ops.

The paper's MPI support hinges on the MPICH ABI compatibility initiative:
implementations that share an ABI string are interchangeable at deployment
time without recompilation.  In a traced/JIT world the binary contract
becomes a *structural* one: two implementations of a logical op are
interchangeable iff

  1. they implement the same logical op name,
  2. they agree on the abstract signature (argument structure, dtypes and
     shape polymorphism expressed as a canonical signature string), and
  3. they share a semantic major version (minor versions are compatible,
     mirroring libtool's ``current:revision:age``).

`AbiString` encodes (1)-(3) into a printable string that can be compared the
way Shifter compares libtool strings before swapping libmpi.
"""

from __future__ import annotations

import dataclasses
import hashlib
import re
from typing import Any, Mapping, Sequence

__all__ = [
    "AbiString",
    "AbiError",
    "AbiIncompatibility",
    "signature_digest",
    "parse_abi",
]

_ABI_RE = re.compile(
    r"^(?P<name>[a-z][a-z0-9_.]*)/"
    r"(?P<major>\d+):(?P<minor>\d+)/"
    r"(?P<digest>[0-9a-f]{12})$"
)


class AbiError(ValueError):
    """Malformed ABI string."""


class AbiIncompatibility(RuntimeError):
    """Raised when a swap is attempted between incompatible ABIs.

    Shifter's behaviour on a libtool-string mismatch is to refuse the swap
    and keep the container's own library; `OpRegistry` mirrors that, using
    this exception (or a warning, in permissive mode) as the refusal signal.
    """

    def __init__(self, want: "AbiString", have: "AbiString", reason: str):
        self.want = want
        self.have = have
        self.reason = reason
        super().__init__(
            f"ABI mismatch for op '{want.name}': required {want} but "
            f"implementation provides {have} ({reason})"
        )


def signature_digest(signature: Mapping[str, Any] | Sequence[Any] | str) -> str:
    """Canonical 12-hex-digit digest of an op's abstract signature.

    The signature is whatever structured description the op author provides
    (argument names, rank constraints, dtype classes...).  It is canonicalised
    via repr of sorted items so dict ordering never changes the digest.
    """

    def _canon(obj: Any) -> str:
        if isinstance(obj, Mapping):
            inner = ",".join(f"{k}={_canon(obj[k])}" for k in sorted(obj))
            return "{" + inner + "}"
        if isinstance(obj, (list, tuple)):
            return "[" + ",".join(_canon(x) for x in obj) + "]"
        return repr(obj)

    blob = _canon(signature).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


@dataclasses.dataclass(frozen=True, order=True)
class AbiString:
    """``name/major:minor/digest`` — the comparable deployment contract."""

    name: str
    major: int
    minor: int
    digest: str

    def __post_init__(self) -> None:
        if not re.match(r"^[a-z][a-z0-9_.]*$", self.name):
            raise AbiError(f"invalid op name {self.name!r}")
        if self.major < 0 or self.minor < 0:
            raise AbiError("versions must be non-negative")
        if not re.match(r"^[0-9a-f]{12}$", self.digest):
            raise AbiError(f"invalid digest {self.digest!r}")

    # -- construction -----------------------------------------------------
    @classmethod
    def make(
        cls,
        name: str,
        signature: Mapping[str, Any] | Sequence[Any] | str,
        major: int = 1,
        minor: int = 0,
    ) -> "AbiString":
        return cls(name=name, major=major, minor=minor,
                   digest=signature_digest(signature))

    # -- comparison --------------------------------------------------------
    def compatible_with(self, other: "AbiString") -> bool:
        """True iff `other` may be substituted where `self` is required.

        Mirrors libtool semantics: same name, same signature digest, same
        major version; the provider's minor version must be >= the required
        minor (newer revisions keep old entry points).
        """
        return (
            self.name == other.name
            and self.digest == other.digest
            and self.major == other.major
            and other.minor >= self.minor
        )

    def why_incompatible(self, other: "AbiString") -> str | None:
        if self.name != other.name:
            return f"op name differs ({self.name} vs {other.name})"
        if self.digest != other.digest:
            return "signature digest differs"
        if self.major != other.major:
            return f"major version differs ({self.major} vs {other.major})"
        if other.minor < self.minor:
            return f"provider minor {other.minor} older than required {self.minor}"
        return None

    def __str__(self) -> str:  # the printable "libtool string"
        return f"{self.name}/{self.major}:{self.minor}/{self.digest}"


def parse_abi(text: str) -> AbiString:
    m = _ABI_RE.match(text.strip())
    if not m:
        raise AbiError(f"malformed ABI string: {text!r}")
    return AbiString(
        name=m.group("name"),
        major=int(m.group("major")),
        minor=int(m.group("minor")),
        digest=m.group("digest"),
    )
