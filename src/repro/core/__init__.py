"""repro.core — the paper's contribution: portable, high-performance
program containers for JAX (ABI-verified op substitution, environment-
triggered resource injection, single-blob image distribution)."""

from repro.core.abi import AbiIncompatibility, AbiString, parse_abi, signature_digest
from repro.core.bundle import Bundle, BundleError
from repro.core.env import parse_visible_devices, resolve_platform, select_devices
from repro.core.gateway import Gateway, GatewayError
from repro.core.platform import (
    CLUSTER,
    LAPTOP,
    MULTIPOD_V5E,
    PLATFORMS,
    POD_V5E,
    TPU_V5E,
    HardwareSpec,
    Platform,
    detect_platform,
)
from repro.core.registry import (
    ImplKind,
    OpBinding,
    OpDecl,
    OpImpl,
    OpRegistry,
    SwapReport,
    global_registry,
)
from repro.core.runtime import Container, DeploymentError, Runtime

__all__ = [
    "AbiIncompatibility", "AbiString", "parse_abi", "signature_digest",
    "Bundle", "BundleError",
    "parse_visible_devices", "resolve_platform", "select_devices",
    "Gateway", "GatewayError",
    "CLUSTER", "LAPTOP", "MULTIPOD_V5E", "PLATFORMS", "POD_V5E", "TPU_V5E",
    "HardwareSpec", "Platform", "detect_platform",
    "ImplKind", "OpBinding", "OpDecl", "OpImpl", "OpRegistry", "SwapReport",
    "global_registry",
    "Container", "DeploymentError", "Runtime",
]
