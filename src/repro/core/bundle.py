"""Bundle — the container image of a JAX program.

A Docker image packs "the application and all the dependencies needed for
its correct execution" and is hardware-agnostic.  `repro`'s Bundle packs the
*program*: the model configuration, the training/serving recipe, the list of
logical ops the program uses (its "dynamic library dependencies"), required
ABI strings for each, and the environment defaults baked at build time.

Like an image, a bundle is identified by content digest and is immutable;
like an image, it may name a *base* bundle it extends (layering), which the
Gateway flattens at pull time.  Weights are NOT inside the bundle — they
live in checkpoint manifests (the persistent volume of the paper §II-A).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Any, Mapping

from repro.core.abi import AbiString, parse_abi

__all__ = ["Bundle", "BundleError"]

_FORMAT_VERSION = 1


class BundleError(ValueError):
    pass


def _digest(payload: Mapping[str, Any]) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":")).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Bundle:
    name: str                                  # e.g. "qwen2.5-14b"
    tag: str                                   # e.g. "latest"
    model_config: Mapping[str, Any]            # arch definition (may be partial if base set)
    recipe: Mapping[str, Any]                  # optimizer/schedule/serving knobs
    required_ops: Mapping[str, str]            # op name -> required ABI string
    env: Mapping[str, str]                     # baked-in environment defaults
    base: str | None = None                    # "name:tag" of a parent bundle
    tuning_bundle: str | None = None           # path/reference of a portable
    # tuning bundle (repro.tuning.bundle) shipped WITH this run bundle: the
    # Runtime auto-imports it before binding, so a laptop-warmed artifact
    # travels inside the deployable unit (overridable by deploy(tuning_bundle=)
    # or REPRO_TUNING_BUNDLE, both of which win over this baked-in default)
    format_version: int = _FORMAT_VERSION

    # -- identity ----------------------------------------------------------
    @property
    def reference(self) -> str:
        return f"{self.name}:{self.tag}"

    @property
    def digest(self) -> str:
        return _digest(self.to_dict())

    def required_abis(self) -> dict[str, AbiString]:
        return {op: parse_abi(text) for op, text in self.required_ops.items()}

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        return {
            "format_version": self.format_version,
            "name": self.name,
            "tag": self.tag,
            "base": self.base,
            "tuning_bundle": self.tuning_bundle,
            "model_config": dict(self.model_config),
            "recipe": dict(self.recipe),
            "required_ops": dict(self.required_ops),
            "env": dict(self.env),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Bundle":
        if d.get("format_version") != _FORMAT_VERSION:
            raise BundleError(
                f"unsupported bundle format {d.get('format_version')!r}"
            )
        try:
            return cls(
                name=d["name"],
                tag=d["tag"],
                base=d.get("base"),
                tuning_bundle=d.get("tuning_bundle"),
                model_config=dict(d["model_config"]),
                recipe=dict(d["recipe"]),
                required_ops=dict(d["required_ops"]),
                env=dict(d["env"]),
            )
        except KeyError as e:  # pragma: no cover - defensive
            raise BundleError(f"bundle missing field {e}") from e

    def save(self, path: Path | str) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True))
        return path

    @classmethod
    def load(cls, path: Path | str) -> "Bundle":
        return cls.from_dict(json.loads(Path(path).read_text()))

    # -- layering -------------------------------------------------------------
    def flatten_onto(self, parent: "Bundle") -> "Bundle":
        """Collapse this bundle onto its base (Gateway 'flatten' step).

        Docker semantics: the child layer wins on conflicts; required_ops
        union with child precedence; env merge likewise.
        """
        if self.base != parent.reference:
            raise BundleError(
                f"{self.reference} declares base {self.base!r}, got {parent.reference}"
            )
        return Bundle(
            name=self.name,
            tag=self.tag,
            base=None,
            tuning_bundle=self.tuning_bundle or parent.tuning_bundle,
            model_config={**parent.model_config, **self.model_config},
            recipe={**parent.recipe, **self.recipe},
            required_ops={**parent.required_ops, **self.required_ops},
            env={**parent.env, **self.env},
        )
