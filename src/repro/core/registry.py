"""OpRegistry — the library-substitution engine (the paper's §IV-B in JAX).

A logical op (e.g. ``attention``, ``rmsnorm``, ``moe_gmm``) is declared once
with its ABI.  Implementations register against it:

  * REFERENCE — pure jnp, hardware-agnostic: the MPICH the image ships with.
  * NATIVE    — site-optimized (Pallas kernel, shard_map collective): the
                Cray MPT the host bind-mounts in.

At deployment the Runtime asks the registry for a *binding*: a frozen
name -> callable table for a given platform with native support on or off.
The swap is refused — keeping the reference — whenever the ABI strings do
not match, the platform lacks the feature the impl requires, or the binding
has been frozen (the privilege-drop analogue: once the container app runs,
it cannot remount libraries).
"""

from __future__ import annotations

import dataclasses
import enum
import logging
from typing import Any, Callable, Iterable, Mapping

from repro.core.abi import AbiIncompatibility, AbiString
from repro.core.platform import Platform

__all__ = [
    "ImplKind",
    "OpImpl",
    "OpDecl",
    "OpRegistry",
    "OpBinding",
    "SwapReport",
    "global_registry",
]

log = logging.getLogger("repro.registry")


class ImplKind(enum.Enum):
    REFERENCE = "reference"
    NATIVE = "native"


@dataclasses.dataclass(frozen=True)
class OpImpl:
    """One implementation of a logical op."""

    abi: AbiString
    kind: ImplKind
    fn: Callable[..., Any]
    requires_feature: str | None = None   # e.g. "pallas_kernels"
    requires_device_kind: str | None = None   # e.g. "tpu": the paper's
    # "the nvidia-uvm driver has to be loaded" precondition — the platform
    # may *declare* the feature, but the device must actually be present.
    provider: str = ""                    # human label ("pallas", "jnp", ...)
    tuner: Any = None                     # optional tuning.OpTuner: lets the
    # bind-time TuningContext specialize this impl to the site (the impl's
    # fn must then accept a ``config=`` keyword).  The registry only
    # carries the hook; it never interprets it.
    config: Any = None                    # tuning.ConfigTable resolved at bind
    # time (set by TuningContext.apply): the per-geometry config table the
    # bound TunedDispatch consults per call — not a single BlockConfig
    # since the geometry-dispatch redesign.  None when untuned.

    def available_on(self, platform: Platform) -> bool:
        if self.requires_feature is not None and not platform.has(self.requires_feature):
            return False
        if self.requires_device_kind is not None:
            import jax

            if jax.default_backend() != self.requires_device_kind:
                return False
        return True


@dataclasses.dataclass(frozen=True)
class OpDecl:
    """The logical op: its required ABI plus registered implementations."""

    abi: AbiString
    impls: tuple[OpImpl, ...] = ()

    @property
    def reference(self) -> OpImpl | None:
        for impl in self.impls:
            if impl.kind is ImplKind.REFERENCE:
                return impl
        return None

    def natives(self) -> tuple[OpImpl, ...]:
        return tuple(i for i in self.impls if i.kind is ImplKind.NATIVE)

    def tunable_native(self, platform: Platform) -> OpImpl | None:
        """The native impl whose tuning-cache entries apply on `platform`:
        first available native carrying a tuner hook.  Cache keys embed
        *this* impl's ABI (which may be a newer minor than the
        declaration), so expiry sweeps and warm runs must both derive the
        current ABI from here, never from the declaration."""
        for impl in self.natives():
            if impl.available_on(platform) and impl.tuner is not None:
                return impl
        return None


@dataclasses.dataclass(frozen=True)
class SwapReport:
    """Per-op outcome of the binding stage, for logs/EXPERIMENTS."""

    op: str
    bound: str          # provider label of the bound impl
    kind: ImplKind
    swapped: bool       # True if a native impl replaced the reference
    reason: str         # why this impl (or why the swap was refused)
    tuning: str = ""    # autotune outcome summary: "cache-hit",
    #                     "cache-miss-searched", "cache-miss-default",
    #                     "search-failed-default", "cache-evicted-lru",
    #                     "bundle-imported"/"bundle-demoted"/
    #                     "bundle-rejected" (tuning-bundle provenance), ...
    #                     or "mixed(...)" when geometries disagree; empty
    #                     when tuning was off or the impl is untunable
    config: str = ""    # the primary (hottest-geometry) BlockConfig, printable
    geometries: tuple = ()        # per-geometry tuning breakdown: one
    #                     tuning.GeometryOutcome per dispatchable shape
    #                     bucket of this op (empty when untuned).  Under a
    #                     table cap this includes the buckets the bind
    #                     SHED ("cache-evicted-lru") — reported for the
    #                     EXPERIMENTS log, absent from the dispatch table
    search_rank: int | None = None   # position in the profile-driven search
    #                     order (1 = hottest op); None when ordering was
    #                     not profile-driven


class OpBinding(Mapping[str, Callable[..., Any]]):
    """Frozen name -> callable table handed to the model at execution."""

    def __init__(self, table: dict[str, OpImpl], reports: list[SwapReport]):
        self._table = dict(table)
        self.reports = tuple(reports)

    def __getitem__(self, name: str) -> Callable[..., Any]:
        return self._table[name].fn

    def impl(self, name: str) -> OpImpl:
        return self._table[name]

    def tuned_config(self, name: str, shapes: Any = None, dtype: str | None = None) -> Any:
        """The BlockConfig the autotuner bound for this op, or None.

        With ``shapes=None`` this is the primary (hottest-geometry)
        config — the pre-dispatch behaviour.  ``shapes`` may also be a
        sequence of arrays/tracers (the call's actual operands) or an
        encoded shape-bucket string (plus ``dtype``), in which case the
        per-geometry table resolves it (exact -> nearest bucket ->
        validated near-dtype borrow -> platform default; an explicit
        shapes string with ``dtype=None`` matches any dtype, hottest
        first).  Lets call sites that historically pass their own tile
        kwargs (the explicit kwarg always wins inside the kernel) defer
        to the site's tuned value when one exists.
        """
        impl = self._table.get(name)
        config = getattr(impl, "config", None) if impl is not None else None
        if config is None or not hasattr(config, "resolve"):
            return config
        if shapes is None:
            return config.primary
        if isinstance(shapes, str):
            return config.resolve(shapes=shapes, dtype=dtype)[0]
        return config.resolve(shapes)[0]

    def __iter__(self):
        return iter(self._table)

    def __len__(self) -> int:
        return len(self._table)

    def describe(self) -> str:
        lines = []
        for r in self.reports:
            mark = "->" if r.swapped else "=="
            line = f"  {r.op:<18} {mark} {r.bound:<12} [{r.kind.value}] {r.reason}"
            if r.tuning:
                line += f" | tune: {r.tuning} ({r.config})"
                # size accounting: serialized bytes of the entries actually
                # backing this op's dispatch state (evicted/rejected
                # geometries hold none by the time the binding exists)
                state = sum(
                    getattr(g, "bytes", 0) for g in r.geometries
                    if g.status not in ("cache-evicted-lru", "bundle-rejected")
                )
                if state:
                    line += f" | state ~{state}B"
                if r.search_rank is not None:
                    line += f" | search#{r.search_rank}"
            lines.append(line)
            if len(r.geometries) > 1:
                for g in r.geometries:
                    lines.append(f"      . {g.describe()}")
        return "\n".join(lines)


class OpRegistry:
    def __init__(self) -> None:
        self._decls: dict[str, OpDecl] = {}
        self._frozen = False

    # -- declaration -------------------------------------------------------
    def declare(self, abi: AbiString) -> None:
        self._check_mutable()
        if abi.name in self._decls:
            existing = self._decls[abi.name].abi
            if existing != abi:
                raise AbiIncompatibility(abi, existing, "redeclaration with different ABI")
            return
        self._decls[abi.name] = OpDecl(abi=abi)

    def register(self, impl: OpImpl, *, strict: bool = True) -> bool:
        """Attach an implementation; the ABI check is the libtool-string check.

        strict=True raises on mismatch (author error); strict=False logs and
        skips (deploy-time permissiveness), returning False.
        """
        self._check_mutable()
        decl = self._decls.get(impl.abi.name)
        if decl is None:
            # first registration of a REFERENCE defines the contract
            if impl.kind is not ImplKind.REFERENCE:
                raise KeyError(
                    f"op '{impl.abi.name}' has no declaration/reference yet; "
                    "register the reference implementation first"
                )
            self._decls[impl.abi.name] = OpDecl(abi=impl.abi, impls=(impl,))
            return True
        if not decl.abi.compatible_with(impl.abi):
            reason = decl.abi.why_incompatible(impl.abi) or "incompatible"
            if strict:
                raise AbiIncompatibility(decl.abi, impl.abi, reason)
            log.warning("refusing registration of %s: %s", impl.abi, reason)
            return False
        self._decls[impl.abi.name] = dataclasses.replace(
            decl, impls=decl.impls + (impl,)
        )
        return True

    # -- binding (the deployment-time swap) ---------------------------------
    def bind(
        self,
        ops: Iterable[str],
        platform: Platform,
        *,
        native: bool,
        freeze: bool = True,
        tuning: Any = None,
    ) -> OpBinding:
        """Produce the frozen op table for this deployment.

        ``native=False`` reproduces `shifter` without ``--mpi``: every op
        keeps its reference implementation.  ``native=True`` swaps each op
        whose platform-available native impl is ABI-compatible; refusals
        fall back to the reference, mirroring the paper's behaviour of
        "leave the container's MPI in place".

        ``tuning`` is an optional tuning.TuningContext: after the swap
        decision, each chosen impl that registered a tuner hook is
        specialized to the site — since the geometry-dispatch redesign
        not to one baked config but to a per-geometry config *table*
        resolved per call at trace time — and the per-geometry outcomes
        land in the SwapReport.
        """
        table: dict[str, OpImpl] = {}
        reports: list[SwapReport] = []
        for name in ops:
            decl = self._decls.get(name)
            if decl is None:
                raise KeyError(f"op '{name}' was never declared/registered")
            ref = decl.reference
            if ref is None:
                raise KeyError(f"op '{name}' lacks a reference implementation")
            chosen, swapped, reason = ref, False, "reference (native support disabled)"
            if native:
                reason = "reference (no native impl registered)"
                for cand in decl.natives():
                    if not cand.available_on(platform):
                        need = cand.requires_feature or (
                            f"{cand.requires_device_kind} device"
                        )
                        reason = (
                            f"reference (native '{cand.provider}' needs "
                            f"'{need}' absent on {platform.name})"
                        )
                        continue
                    why = decl.abi.why_incompatible(cand.abi)
                    if why is not None:
                        reason = f"reference (ABI refusal: {why})"
                        log.warning("op %s: refusing native swap: %s", name, why)
                        continue
                    chosen, swapped = cand, True
                    reason = f"native swap ({cand.provider}, abi {cand.abi})"
                    break
            tune_status, config_str = "", ""
            geometries, search_rank = (), None
            if tuning is not None:
                chosen, outcome = tuning.apply(name, chosen)
                if outcome is not None:
                    tune_status, config_str = outcome.status, outcome.config
                    geometries = outcome.geometries
                    search_rank = outcome.search_rank
            table[name] = chosen
            reports.append(
                SwapReport(op=name, bound=chosen.provider or chosen.kind.value,
                           kind=chosen.kind, swapped=swapped, reason=reason,
                           tuning=tune_status, config=config_str,
                           geometries=geometries, search_rank=search_rank)
            )
        if freeze:
            self._frozen = True
        return OpBinding(table, reports)

    # -- lifecycle -----------------------------------------------------------
    @property
    def frozen(self) -> bool:
        return self._frozen

    def thaw(self) -> None:
        """Cleanup-stage reset (tests / successive deployments in-process)."""
        self._frozen = False

    def _check_mutable(self) -> None:
        if self._frozen:
            raise RuntimeError(
                "registry is frozen: ops cannot be (re)registered after the "
                "runtime dropped privileges and started execution"
            )

    def declared(self) -> tuple[str, ...]:
        return tuple(sorted(self._decls))

    def decl(self, name: str) -> OpDecl:
        return self._decls[name]


# The process-global registry the kernels/ package populates on import.
global_registry = OpRegistry()
