"""Site platform descriptors — the "systems evaluated" of the paper (§V-A).

A `Platform` is the analogue of a host system entry (Laptop / Linux Cluster /
Piz Daint): it describes the hardware the runtime may bind a bundle to —
device kind, counts, interconnect tiers — plus the constants the roofline
analysis needs.  Detection mirrors Shifter's behaviour: the runtime inspects
the environment (device kind, REPRO_* variables) and selects the matching
platform; nothing about the bundle changes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax

__all__ = [
    "HardwareSpec",
    "Platform",
    "LAPTOP",
    "CLUSTER",
    "POD_V5E",
    "MULTIPOD_V5E",
    "PLATFORMS",
    "detect_platform",
    "TPU_V5E",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip capability constants (used by roofline + schedulers)."""

    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bytes: float            # bytes of device memory per chip
    hbm_bandwidth: float        # bytes/s per chip
    ici_bandwidth: float        # bytes/s per link (intra-pod interconnect)
    dcn_bandwidth: float        # bytes/s per host (inter-pod network)
    ici_links: int = 4          # links per chip (2D torus -> 4)


# Target accelerator for this reproduction (assignment constants).
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bytes=16e9,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    dcn_bandwidth=25e9 / 8,     # ~25 Gbit/s effective per host, in bytes/s
)

# Commodity CPU "laptop" — the build-and-test environment of the paper's
# workflow (Fig. 2 step 1-2).  Constants are nominal; they only matter for
# relative reporting in benchmarks.
CPU_HOST = HardwareSpec(
    name="cpu-host",
    peak_flops_bf16=2e11,
    hbm_bytes=8e9,
    hbm_bandwidth=4e10,
    ici_bandwidth=1e9,
    dcn_bandwidth=1e9,
)


@dataclasses.dataclass(frozen=True)
class Platform:
    """A deployable site: hardware + topology + which native features exist.

    `native_features` lists the host resources the runtime may inject — the
    analogue of the host's CUDA driver stack and vendor MPI.  A bundle
    deployed on a platform lacking a feature silently keeps its reference
    implementation, exactly like Shifter with `--mpi` unavailable.
    """

    name: str
    hardware: HardwareSpec
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    native_features: frozenset[str] = frozenset()
    description: str = ""

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n

    @property
    def num_pods(self) -> int:
        return self.mesh_shape[self.mesh_axes.index("pod")] if "pod" in self.mesh_axes else 1

    def has(self, feature: str) -> bool:
        return feature in self.native_features


LAPTOP = Platform(
    name="laptop",
    hardware=CPU_HOST,
    mesh_shape=(1,),
    mesh_axes=("data",),
    native_features=frozenset(),
    description="single-device commodity host; reference ops only (build/test)",
)

CLUSTER = Platform(
    name="cluster",
    hardware=CPU_HOST,
    mesh_shape=(8,),
    mesh_axes=("data",),
    native_features=frozenset({"native_collectives"}),
    description="small multi-device host (8 local devices); flat collectives",
)

POD_V5E = Platform(
    name="pod-v5e",
    hardware=TPU_V5E,
    mesh_shape=(16, 16),
    mesh_axes=("data", "model"),
    native_features=frozenset({"pallas_kernels", "native_collectives"}),
    description="single TPU v5e pod slice, 256 chips, 2D ICI torus",
)

MULTIPOD_V5E = Platform(
    name="multipod-v5e",
    hardware=TPU_V5E,
    mesh_shape=(2, 16, 16),
    mesh_axes=("pod", "data", "model"),
    native_features=frozenset(
        {"pallas_kernels", "native_collectives", "hierarchical_collectives",
         "gradient_compression"}
    ),
    description="2 x v5e pod over DCN; hierarchical collectives on the pod axis",
)

# CPU host that runs the Pallas kernels through the interpreter — used to
# validate the full swap path (binding reports + numerics) without a TPU.
POD_SIM = Platform(
    name="pod-sim",
    hardware=CPU_HOST,
    mesh_shape=(1,),
    mesh_axes=("data",),
    native_features=frozenset({"pallas_interpret", "native_collectives"}),
    description="CPU simulation host: Pallas kernels in interpret mode",
)

PLATFORMS: dict[str, Platform] = {
    p.name: p for p in (LAPTOP, CLUSTER, POD_V5E, MULTIPOD_V5E, POD_SIM)
}


def detect_platform(devices: Sequence[jax.Device] | None = None) -> Platform:
    """Auto-detect the site, CUDA_VISIBLE_DEVICES-style.

    Order of precedence mirrors Shifter: explicit environment request
    (handled by env.resolve_platform, which calls this as fallback), then
    device inspection.
    """
    devices = list(devices if devices is not None else jax.devices())
    kind = devices[0].platform if devices else "cpu"
    n = len(devices)
    if kind == "tpu":
        return MULTIPOD_V5E if n > 256 else POD_V5E
    if n >= 8:
        return CLUSTER
    return LAPTOP
