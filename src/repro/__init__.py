"""repro — portable, high-performance program containers for JAX.

Reproduction of "Portable, high-performance containers for HPC"
(Benedicic et al., 2017) with the container/runtime split rebuilt around
JAX: ABI-verified op substitution (core), Pallas TPU kernels (kernels),
site autotuning with a persistent cache (tuning), and the paper's
deployment/benchmark workflow (launch, benchmarks/).
"""

__version__ = "0.1.0"
