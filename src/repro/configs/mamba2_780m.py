"""mamba2-780m — Mamba-2 780M, SSD (state-space duality, arXiv:2405.21060).

48L d_model=1536, attention-free, vocab=50280, ssm_state=128,
expand=2 (d_inner=3072), headdim=64 -> 48 SSD heads.
[arXiv:2405.21060; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    tie_embeddings=True,
    notes="[arXiv:2405.21060; unverified] SSD (state-space duality)",
)
