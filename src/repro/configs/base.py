"""Config system: one `ModelConfig` covers all ten assigned architectures.

Every field corresponds to a published hyper-parameter of the assigned
configs (see configs/<id>.py); `reduced()` produces the CPU smoke-test
variant of the same family (small layers/width/experts/vocab), as required
by the assignment.  Shape cells (seq_len x global_batch x step kind) are
defined here too so every (arch x shape) pair is well-defined.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # norm / activation
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    activation: str = "silu_glu"    # silu_glu | gelu
    # mixture of experts
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # expert hidden size (0 -> d_ff)
    n_shared_experts: int = 0
    moe_every: int = 1              # layer i hosts MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    # hybrid / state-space
    attn_every: int = 1             # hybrid: layer i is attention iff i % attn_every == 0
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # encoder-decoder (audio family)
    encoder_layers: int = 0
    # modality frontend stub: input_specs() provides precomputed embeddings
    modality: str = "text"          # text | vision | audio
    n_patches: int = 0              # vlm: patch embeddings prepended to tokens
    # numerics / training
    dtype: str = "bfloat16"
    remat: str = "full"             # none | dots | full
    tie_embeddings: bool = False
    # free-form provenance notes (source tags from the assignment)
    notes: str = ""

    # -- derived ------------------------------------------------------------
    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim if self.ssm_state else 0

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return i % self.attn_every == 0
        return True

    def is_moe_layer(self, i: int) -> bool:
        return self.num_experts > 0 and i % self.moe_every == self.moe_offset

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts — drives MODEL_FLOPS."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        active = emb
        n_layers = self.num_layers + self.encoder_layers
        for i in range(n_layers):
            dec_i = i - self.encoder_layers
            is_dec = dec_i >= 0
            li = dec_i if is_dec else i
            # attention (+ cross attention for decoder of enc-dec)
            if (not is_dec) or self.is_attn_layer(li):
                qkv = d * self.num_heads * self.head_dim + 2 * d * self.num_kv_heads * self.head_dim
                out = self.num_heads * self.head_dim * d
                att = qkv + out
                if is_dec and self.is_enc_dec:
                    att *= 2  # self + cross attention
                total += att
                active += att
            elif self.ssm_state:
                din = self.ssm_d_inner
                ssm = d * (2 * din + 2 * self.ssm_state + self.ssm_heads) + din * d
                total += ssm
                active += ssm
            # mlp / moe
            if self.d_ff or self.num_experts:
                if is_dec and self.is_moe_layer(li) and self.num_experts:
                    per_expert = 3 * d * self.expert_d_ff
                    total += self.num_experts * per_expert
                    active += (self.top_k + self.n_shared_experts) * per_expert
                elif self.d_ff:
                    per = d * self.d_ff * (3 if self.activation == "silu_glu" else 2)
                    total += per
                    active += per
        return total, active

    # -- smoke-test variant ----------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        hybrid = self.family == "hybrid"
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=4 if hybrid else 2,
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16 if self.head_dim else 0,
            d_ff=96 if self.d_ff else 0,
            vocab_size=256,
            num_experts=4 if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.num_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            attn_every=2 if hybrid else self.attn_every,
            n_patches=8 if self.n_patches else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=8 if self.ssm_state else 64,
            ssm_chunk=16,
            encoder_layers=2 if self.encoder_layers else 0,
            remat="none",
            dtype="float32",
        )

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ModelConfig":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, name=self.name + "-reduced",
            seq_len=min(self.seq_len, 32), global_batch=min(self.global_batch, 4),
        )


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules for which (arch x shape) cells run.

    `long_500k` needs sub-quadratic attention: run for ssm/hybrid, skip for
    pure full-attention archs (documented in DESIGN.md §4).
    """
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("SKIP: long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention ({cfg.family})")
    return True, "ok"
