"""llava-next-34b — LLaVA-NeXT 34B (Yi-34B backbone), anyres tiling.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The vision frontend (anyres tiling + projector) is a STUB per assignment:
input_specs() provides precomputed patch embeddings; the transformer
backbone is fully modeled.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    rope_theta=5e6,
    modality="vision",
    n_patches=576,
    notes="[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] anyres tiling stubbed",
)
