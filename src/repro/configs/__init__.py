"""Assigned-architecture registry: ``--arch <id>`` resolves here."""

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from repro.configs.granite_3_8b import CONFIG as GRANITE_3_8B
from repro.configs.jamba_15_large_398b import CONFIG as JAMBA_15_LARGE
from repro.configs.llava_next_34b import CONFIG as LLAVA_NEXT_34B
from repro.configs.mamba2_780m import CONFIG as MAMBA2_780M
from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.moonshot_v1_16b_a3b import CONFIG as MOONSHOT_16B
from repro.configs.phi35_moe_42b_a66b import CONFIG as PHI35_MOE
from repro.configs.qwen2_72b import CONFIG as QWEN2_72B
from repro.configs.qwen25_14b import CONFIG as QWEN25_14B
from repro.configs.whisper_base import CONFIG as WHISPER_BASE

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        MOONSHOT_16B,
        PHI35_MOE,
        LLAVA_NEXT_34B,
        WHISPER_BASE,
        MINITRON_8B,
        QWEN2_72B,
        GRANITE_3_8B,
        QWEN25_14B,
        JAMBA_15_LARGE,
        MAMBA2_780M,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown --arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(shape: str) -> ShapeConfig:
    if shape not in SHAPES:
        raise KeyError(f"unknown --shape {shape!r}; known: {sorted(SHAPES)}")
    return SHAPES[shape]


__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "ShapeConfig",
    "get_config", "get_shape", "shape_applicable",
]
