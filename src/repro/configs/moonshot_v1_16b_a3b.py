"""moonshot-v1-16b-a3b — Moonlight-16B-A3B (kimi/moonshot).

48L d_model=2048 16H (GQA kv=16 -> effectively MHA) d_ff=1408(expert)
vocab=163840, MoE 64 experts top-6, 2 shared experts.
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=163_840,
    num_experts=64,
    top_k=6,
    n_shared_experts=2,
    rope_theta=5e4,
    notes="[hf:moonshotai/Moonlight-16B-A3B; hf] 64e top-6 + 2 shared experts",
)
