"""whisper-base — encoder-decoder speech model (arXiv:2212.04356).

6L(+6L encoder) d_model=512 8H d_ff=2048 vocab=51865; conv audio frontend
is a STUB per assignment: input_specs() provides precomputed frame
embeddings (post-conv).  LayerNorm + GELU, no rotary (learned/sinusoidal
positions -> modeled as learned positional embeddings).
[arXiv:2212.04356; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    norm="layernorm",
    activation="gelu",
    modality="audio",
    notes="[arXiv:2212.04356; unverified] enc-dec, conv frontend stubbed",
)
