"""jamba-1.5-large-398b — AI21 Jamba 1.5 Large (arXiv:2403.19887).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2,
Mamba:attention 7:1 interleave (attention at layer i where i % 8 == 0),
MoE on every other layer.  [arXiv:2403.19887; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    moe_d_ff=24_576,
    vocab_size=65_536,
    num_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    rope_theta=1e4,
    notes="[arXiv:2403.19887; hf] Mamba+attn 1:7 interleave, MoE every 2nd layer",
)
